//! Safety audit (T2): for every rule and dataset family, solve to a
//! 1e−9 duality gap and verify that no screened feature is active at
//! the optimum. Safe rules must report **zero** violations; the strong
//! rule is the unsafe comparator and may violate.
//!
//! ```bash
//! cargo run --release --example safety_audit
//! ```

use svmscreen::data::synth::SynthSpec;
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::screening::rule::{screen_all, RuleKind};
use svmscreen::solver::api::{solve, SolveOptions};

fn main() -> Result<()> {
    let specs = [
        SynthSpec::dense(150, 120, 1001),
        SynthSpec::text(200, 500, 1002),
        SynthSpec::corr(120, 100, 1003),
    ];
    let fracs = [0.95, 0.8, 0.6, 0.4, 0.2];
    let rules =
        [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong];

    let mut table = Table::new(
        "T2: safety audit (violations MUST be 0 for safe rules)",
        &["dataset", "rule", "checked", "screened", "violations", "min margin"],
    );

    for spec in specs {
        let p = Problem::from_dataset(&spec.generate());
        // Screen from an *interior* dual point (λ₁ = 0.8·λ_max, solved to
        // 1e-9): at λ_max the half-space normal degenerates to ∝y and the
        // paper rule coincides with the ball rule; the interior point is
        // where the full geometry engages.
        let lambda1 = 0.8 * p.lambda_max();
        let at_l1 = solve(
            SolverKind::Cd,
            &p.x,
            &p.y,
            lambda1,
            None,
            &SolveOptions::precise(),
        )?;
        assert!(at_l1.converged);
        let theta1 = svmscreen::svm::dual::theta_from_primal(
            &p.x, &p.y, &at_l1.w, at_l1.b, lambda1,
        );
        for rule in rules {
            let mut screened_total = 0usize;
            let mut violations = 0usize;
            let mut min_margin = f64::INFINITY;
            for &frac in &fracs {
                let lambda2 = frac * lambda1;
                let exact = solve(
                    SolverKind::Cd,
                    &p.x,
                    &p.y,
                    lambda2,
                    None,
                    &SolveOptions::precise(),
                )?;
                assert!(exact.converged, "precise solve failed");
                let rep = screen_all(rule, &p.x, &p.y, &theta1, lambda1, lambda2)?;
                // Bound tightness: how close do kept-feature bounds come
                // to the threshold (margin below 1 = how much slack the
                // screened features had).
                for j in 0..p.m() {
                    if !rep.keep[j] {
                        screened_total += 1;
                        if rep.bounds[j].is_finite() {
                            min_margin = min_margin.min(1.0 - rep.bounds[j]);
                        }
                        if exact.w[j].abs() > 1e-7 {
                            violations += 1;
                        }
                    }
                }
            }
            if rule.is_safe() {
                assert_eq!(
                    violations, 0,
                    "SAFETY VIOLATION: rule {} on {}",
                    rule.name(),
                    p.name
                );
            }
            table.row(&[
                p.name.clone(),
                rule.name().to_string(),
                (fracs.len() * p.m()).to_string(),
                screened_total.to_string(),
                violations.to_string(),
                if min_margin.is_finite() {
                    format!("{min_margin:.4}")
                } else {
                    "-".into()
                },
            ]);
        }
    }
    println!("{table}");
    println!("all safe rules: 0 violations ✔");
    Ok(())
}
