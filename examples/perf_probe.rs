//! Perf probe: the §Perf measurement workloads (EXPERIMENTS.md).
//! Run after any hot-path change:
//! `cargo run --release --example perf_probe`

use svmscreen::data::synth::SynthSpec;
use svmscreen::report::timer::BenchStats;
use svmscreen::screening::rule::{screen_all, RuleKind};
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};
use svmscreen::svm::problem::Problem;
fn main() {
    let ds = SynthSpec::text(2000, 20000, 42).generate();
    let p = Problem::from_dataset(&ds);
    let lam = 0.2 * p.lambda_max();
    // CD solve cold
    let s = BenchStats::measure(1, 3, || {
        let r = solve(SolverKind::Cd, &p.x, &p.y, lam, None, &SolveOptions::default()).unwrap();
        assert!(r.converged);
    });
    println!("cd-solve-cold text-2k-20k @0.2lmax: {}", s.display());
    // screening pass
    let th = p.theta_at_lambda_max().theta();
    let s = BenchStats::measure(2, 10, || {
        screen_all(RuleKind::Paper, &p.x, &p.y, &th, p.lambda_max(), 0.5 * p.lambda_max()).unwrap();
    });
    println!("screen-native text-2k-20k: {} ({:.0} feat/s)", s.display(), 20000.0 / s.median());
    // dense CD
    let ds = SynthSpec::dense(1000, 2000, 43).generate();
    let p = Problem::from_dataset(&ds);
    let lam = 0.2 * p.lambda_max();
    let s = BenchStats::measure(1, 3, || {
        let r = solve(SolverKind::Cd, &p.x, &p.y, lam, None, &SolveOptions::default()).unwrap();
        assert!(r.converged);
    });
    println!("cd-solve-cold dense-1k-2k @0.2lmax: {}", s.display());
}
