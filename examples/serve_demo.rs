//! Screening-as-a-service demo: starts the batched screening server
//! in-process, drives it with concurrent clients exploring different λ,
//! and reports latency + batching behaviour (T5's workload).
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use std::time::Instant;
use svmscreen::coordinator::batcher::BatchPolicy;
use svmscreen::coordinator::protocol::Json;
use svmscreen::coordinator::server::{Client, ScreeningServer, ServerConfig};
use svmscreen::prelude::*;
use svmscreen::report::timer::BenchStats;

fn main() -> Result<()> {
    let ds = svmscreen::data::synth::SynthSpec::text(1000, 10000, 77).generate();
    println!("serving {}", ds.describe());
    let problem = Problem::from_dataset(&ds);
    let lmax = problem.lambda_max();

    let server = ScreeningServer::start(
        problem,
        ServerConfig {
            batch: BatchPolicy {
                max_batch: 16,
                window: std::time::Duration::from_millis(4),
            },
            ..Default::default()
        },
    )?;
    let addr = server.addr;
    println!("listening on {addr}");

    // Move the dual point into the interior so screening is interesting.
    let mut c = Client::connect(addr)?;
    let sol = c.request(&Json::obj(vec![
        ("cmd", Json::Str("solve".into())),
        ("lambda", Json::Num(0.7 * lmax)),
    ]))?;
    println!(
        "server solved lambda1 = 0.7 lmax: nnz = {}, gap = {:?}",
        sol.get("nnz").unwrap().as_f64().unwrap(),
        sol.get("rel_gap").unwrap().as_f64().unwrap()
    );

    // 8 concurrent clients, each sweeping its own lambda ladder.
    let t0 = Instant::now();
    let lambda1 = 0.7 * lmax;
    let handles: Vec<_> = (0..8)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).expect("connect");
                let mut latencies = Vec::new();
                let mut batch_sizes = Vec::new();
                for step in 0..12 {
                    // Each client walks its own ladder strictly below λ₁.
                    let frac = 0.95 - 0.06 * step as f64 - 0.005 * k as f64;
                    let t = Instant::now();
                    let rep = c
                        .request(&Json::obj(vec![
                            ("cmd", Json::Str("screen".into())),
                            ("lambda2", Json::Num(frac * lambda1)),
                        ]))
                        .expect("request");
                    assert_eq!(
                        rep.get("ok"),
                        Some(&Json::Bool(true)),
                        "screen failed: {rep:?}"
                    );
                    latencies.push(t.elapsed().as_secs_f64());
                    batch_sizes.push(
                        rep.get("batch_size").and_then(|v| v.as_f64()).unwrap_or(1.0),
                    );
                }
                (latencies, batch_sizes)
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut all_batch = Vec::new();
    for h in handles {
        let (lat, bat) = h.join().expect("client thread");
        all_lat.extend(lat);
        all_batch.extend(bat);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = BenchStats::from_samples(all_lat);
    let mean_batch: f64 = all_batch.iter().sum::<f64>() / all_batch.len() as f64;
    let (screens, batches, solves) = server.metrics();
    println!(
        "served {screens} screen requests in {batches} batches ({solves} solves) \
         over {wall:.2}s"
    );
    println!("request latency: {}", stats.display());
    println!("mean batch size: {mean_batch:.2} (window 4ms, max 16)");
    println!(
        "throughput: {:.0} screen requests/s",
        screens as f64 / wall
    );

    // Live stats over the wire: the server's own view of the workload
    // (request counters, latency percentiles, batch coalescing).
    let stats = c.request(&Json::obj(vec![
        ("cmd", Json::Str("stats".into())),
        ("prometheus", Json::Bool(true)),
    ]))?;
    let metrics = stats.get("metrics").expect("stats.metrics");
    let requests = metrics
        .get("counters")
        .and_then(|c| c.get("server.requests"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let p99 = metrics
        .get("histograms")
        .and_then(|h| h.get("server.screen.seconds"))
        .and_then(|h| h.get("p99"))
        .and_then(|v| v.as_f64())
        .unwrap_or(f64::NAN);
    println!("server-side stats: {requests} requests, screen p99 {p99:.4}s");
    if let Some(text) = stats.get("prometheus").and_then(|v| v.as_str()) {
        let preview: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("server_"))
            .take(6)
            .collect();
        println!("prometheus rendering (server_* excerpt):");
        for line in preview {
            println!("  {line}");
        }
    }
    server.shutdown();
    Ok(())
}
