//! Native vs AOT/PJRT screening: decision agreement and timing on the
//! same workload — the three-layer architecture exercised end to end
//! (rust coordinator → compiled JAX/Pallas HLO via PJRT).
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example pjrt_compare
//! ```

use std::time::Instant;
use svmscreen::prelude::*;
use svmscreen::runtime::{screen_all_pjrt, PjrtEngine, PjrtScreenOptions};
use svmscreen::screening::rule::screen_all;

fn main() -> Result<()> {
    let dir = PjrtEngine::default_dir();
    if !dir.exists() {
        eprintln!("artifact dir {dir:?} missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let t0 = Instant::now();
    let engine = PjrtEngine::load(&dir)?;
    println!("engine loaded in {:.2}s: {engine:?}", t0.elapsed().as_secs_f64());

    let ds = svmscreen::data::synth::SynthSpec::text(1000, 8000, 11).generate();
    println!("workload: {}", ds.describe());
    let p = Problem::from_dataset(&ds);
    let theta1 = p.theta_at_lambda_max().theta();
    let l1 = p.lambda_max();

    for frac in [0.9, 0.6, 0.3] {
        let l2 = frac * l1;
        let t = Instant::now();
        let native = screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, l1, l2)?;
        let t_native = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let pjrt = screen_all_pjrt(
            &engine,
            &p.x,
            &p.y,
            &theta1,
            l1,
            l2,
            &PjrtScreenOptions::default(),
        )?;
        let t_pjrt = t.elapsed().as_secs_f64();
        let agree = native
            .keep
            .iter()
            .zip(&pjrt.keep)
            .filter(|(a, b)| a == b)
            .count();
        let unsafe_drops = native
            .keep
            .iter()
            .zip(&pjrt.keep)
            .filter(|(n, p)| **n && !**p)
            .count();
        println!(
            "lambda2 = {frac:.1}·lmax | native: {:5} screened in {:7.1}ms | \
             pjrt: {:5} screened in {:7.1}ms | agree {agree}/{} | \
             native-kept-but-pjrt-dropped: {unsafe_drops} (must be 0)",
            native.n_screened(),
            1e3 * t_native,
            pjrt.n_screened(),
            1e3 * t_pjrt,
            p.m(),
        );
        assert_eq!(unsafe_drops, 0, "PJRT must keep a superset (keep margin)");
    }
    println!("\nnote: the PJRT path runs the Pallas kernel in interpret mode on");
    println!("CPU — its wallclock is a correctness demo, not a TPU perf proxy");
    println!("(see DESIGN.md §Hardware-Adaptation for the TPU estimate).");
    Ok(())
}
