//! Quickstart: generate data, inspect λ_max, screen once, solve once.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use svmscreen::prelude::*;
use svmscreen::screening::rule::screen_all;
use svmscreen::solver::api::{solve, SolveOptions};

fn main() -> Result<()> {
    // 1. A small synthetic text-classification dataset (deterministic).
    let ds = svmscreen::data::synth::SynthSpec::text(500, 2000, 42).generate();
    println!("dataset: {}", ds.describe());

    // 2. Bind it to the sparse-SVM model: λ_max comes in closed form
    //    (Eq. 26 of the paper), as does the dual point at λ_max.
    let problem = Problem::from_dataset(&ds);
    println!("lambda_max = {:.6}", problem.lambda_max());
    println!(
        "first feature(s) to activate: {:?}",
        problem.lambda_max_stats().first_features
    );

    // 3. Screen for λ = 0.5·λ_max using the paper's rule.
    let theta1 = problem.theta_at_lambda_max().theta();
    let lambda2 = 0.5 * problem.lambda_max();
    let screen = screen_all(
        RuleKind::Paper,
        &problem.x,
        &problem.y,
        &theta1,
        problem.lambda_max(),
        lambda2,
    )?;
    println!(
        "screening: discarded {} / {} features ({:.1}%) in {:.2}ms",
        screen.n_screened(),
        problem.m(),
        100.0 * screen.rejection_ratio(),
        1e3 * screen.seconds
    );

    // 4. Solve the reduced problem and confirm the certificate.
    let reduced =
        svmscreen::solver::reduced::ReducedProblem::build(&problem.x, screen.kept_indices())?;
    let rep = reduced.solve(SolverKind::Cd, &problem.y, lambda2, None, &SolveOptions::default())?;
    println!(
        "solved: nnz = {}, rel duality gap = {:.2e}, {:.1}ms",
        rep.nnz(),
        rep.gap.rel_gap,
        1e3 * rep.seconds
    );

    // 5. Sanity: solving the FULL problem gives the same objective.
    let full = solve(
        SolverKind::Cd,
        &problem.x,
        &problem.y,
        lambda2,
        None,
        &SolveOptions::default(),
    )?;
    println!(
        "objective screened = {:.8}  full = {:.8}  (safe: identical)",
        rep.gap.primal, full.gap.primal
    );
    Ok(())
}
