//! END-TO-END driver (the headline experiment, recorded in
//! EXPERIMENTS.md): train a full regularization path on a realistic
//! synthetic text-classification workload with and without safe
//! screening, and report the F1 rejection curve plus the T1 speedup row.
//!
//! ```bash
//! cargo run --release --example path_screening             # full (n=2000, m=20000)
//! cargo run --release --example path_screening -- --small  # CI-sized
//! ```

use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;

fn main() -> Result<()> {
    let small = std::env::args().any(|a| a == "--small");
    let (n, m, steps) = if small { (400, 4000, 20) } else { (2000, 20000, 50) };

    let ds = svmscreen::data::synth::SynthSpec::text(n, m, 42).generate();
    println!("workload: {}", ds.describe());
    let problem = Problem::from_dataset(&ds);
    let grid = geometric(problem.lambda_max(), 0.05, steps)?;
    println!(
        "path: {} lambdas, lambda_max = {:.4}, down to {:.2}% of lambda_max\n",
        steps,
        problem.lambda_max(),
        100.0 * 0.05
    );

    let mut rows: Vec<(RuleKind, f64, f64, f64, f64)> = Vec::new();
    let mut screened_report = None;
    for rule in [RuleKind::None, RuleKind::Sphere, RuleKind::BallEq, RuleKind::Paper] {
        let cfg = PathConfig { rule, ..Default::default() };
        let rep = run_path(&problem, &grid, &cfg)?;
        let t = rep.totals();
        println!(
            "rule={:<7} total {:>8.3}s  (screen {:>7.3}s solve {:>8.3}s)  mean rejection {:>5.1}%",
            rule.name(),
            rep.total_seconds,
            t.screen_seconds,
            t.solve_seconds,
            100.0 * t.mean_rejection
        );
        rows.push((
            rule,
            rep.total_seconds,
            t.screen_seconds,
            t.solve_seconds,
            t.mean_rejection,
        ));
        if rule == RuleKind::Paper {
            screened_report = Some(rep);
        }
    }

    // T1-style speedup table.
    let baseline = rows[0].1;
    let mut t1 = Table::new(
        "T1: end-to-end path time (paper-shaped: safe rules preserve the path, \
         paper rule fastest)",
        &["rule", "total_s", "screen_s", "solve_s", "mean_reject%", "speedup"],
    );
    for (rule, total, screen, solve, rej) in &rows {
        t1.row(&[
            rule.name().to_string(),
            format!("{total:.3}"),
            format!("{screen:.3}"),
            format!("{solve:.3}"),
            format!("{:.1}", 100.0 * rej),
            format!("{:.2}x", baseline / total),
        ]);
    }
    println!("\n{t1}");

    // F1-style rejection curve for the paper rule.
    let rep = screened_report.unwrap();
    let mut f1 = Table::new(
        "F1: rejection ratio along the path (paper rule)",
        &["lambda/lmax", "screened", "kept", "reject%", "nnz"],
    );
    for s in &rep.steps {
        f1.row(&[
            format!("{:.4}", s.lambda_frac),
            s.screened.to_string(),
            s.kept.to_string(),
            format!("{:.1}", 100.0 * s.rejection),
            s.nnz.to_string(),
        ]);
    }
    println!("{f1}");

    // CSV artifacts for the experiment log.
    let rows_csv: Vec<Vec<String>> = rep.steps.iter().map(|s| s.row().to_vec()).collect();
    svmscreen::report::csv::write_file(
        "target/experiments/path_screening_f1.csv",
        &svmscreen::path::stats::PathStep::header(),
        &rows_csv,
    )?;
    println!("wrote target/experiments/path_screening_f1.csv");
    Ok(())
}
