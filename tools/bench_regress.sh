#!/bin/sh
# Bench regression gate: compares fresh BENCH_<id>.json artifacts
# (written by `cargo bench` into rust/) against the committed baselines
# in tools/baselines/, and fails when wall_seconds regressed by more
# than REGRESS_PCT percent (default 20).
#
# Usage: sh tools/bench_regress.sh t1 f1 f2 f5
#
# Baselines are seeded from a CI run's bench-artifacts upload: download
# the artifact, copy the BENCH_<id>.json files into tools/baselines/,
# and commit them (see tools/baselines/README.md). A missing baseline
# is reported but never fails the gate, so the first run on a new bench
# passes and produces the file to commit.
set -u

: "${REGRESS_PCT:=20}"
fresh_dir="rust"
base_dir="tools/baselines"
status=0

field() { grep -o "\"$2\"[: ]*[0-9.eE+-]*" "$1" | head -1 | cut -d: -f2 | tr -d ' '; }

for id in "$@"; do
  fresh="$fresh_dir/BENCH_${id}.json"
  base="$base_dir/BENCH_${id}.json"
  if [ ! -f "$fresh" ]; then
    echo "bench-regress: $id: no fresh artifact at $fresh (bench skipped or failed); skipping"
    continue
  fi
  if [ ! -f "$base" ]; then
    echo "bench-regress: $id: no baseline at $base; seed it from this run's artifact"
    continue
  fi
  fresh_s=$(field "$fresh" wall_seconds)
  base_s=$(field "$base" wall_seconds)
  if [ -z "$fresh_s" ] || [ -z "$base_s" ]; then
    echo "bench-regress: $id: missing wall_seconds (fresh='$fresh_s' base='$base_s'); skipping"
    continue
  fi
  verdict=$(awk -v f="$fresh_s" -v b="$base_s" -v pct="$REGRESS_PCT" 'BEGIN {
    if (b <= 0) { print "skip"; exit }
    delta = 100 * (f - b) / b;
    printf "%s %.1f", (delta > pct ? "FAIL" : "ok"), delta;
  }')
  case "$verdict" in
    skip)
      echo "bench-regress: $id: baseline wall_seconds is zero; skipping" ;;
    FAIL*)
      echo "bench-regress: $id: FAIL — wall ${fresh_s}s vs baseline ${base_s}s (${verdict#FAIL }% > ${REGRESS_PCT}%)"
      status=1 ;;
    *)
      echo "bench-regress: $id: ok — wall ${fresh_s}s vs baseline ${base_s}s (${verdict#ok }%)" ;;
  esac
done

exit $status
