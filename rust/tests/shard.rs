//! Sharded-screening correctness guarantees: the sharded coordinator's
//! merged kept sets (and raw bounds) are bit-identical to the unsharded
//! sweep across every rule, storage backend and shard count, and the
//! sharded server exposes per-shard metrics through `{"cmd":"stats"}`.

use svmscreen::coordinator::protocol::Json;
use svmscreen::coordinator::server::{Client, ScreeningServer, ServerConfig};
use svmscreen::coordinator::ShardedScreener;
use svmscreen::data::synth::SynthSpec;
use svmscreen::screening::rule::{screen_multi_with, RuleKind};
use svmscreen::svm::problem::Problem;

const RULES: [RuleKind; 4] =
    [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong];

/// Every rule × {dense, sparse} × K ∈ {1, 3, m}: merged shard output is
/// the unsharded output to the last bit — same keep decisions AND same
/// bound values, at both a near-λ_max and a deep-path target.
#[test]
fn sharded_bit_identical_to_unsharded() {
    let specs = [SynthSpec::dense(40, 60, 911), SynthSpec::text(60, 240, 912)];
    for spec in specs {
        let p = Problem::from_dataset(&spec.generate());
        let m = p.m();
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        let l2s = [0.9 * l1, 0.3 * l1];
        for rule in RULES {
            let reference = screen_multi_with(
                rule,
                &p.x,
                &p.y,
                &theta1,
                l1,
                &l2s,
                Some(p.cache()),
            )
            .unwrap();
            for k in [1, 3, m] {
                let sc = ShardedScreener::build(&p, k, 2).unwrap();
                let sharded =
                    sc.screen_multi(rule, &p.y, &theta1, l1, &l2s).unwrap();
                assert_eq!(sharded.len(), reference.len());
                for (s, r) in sharded.iter().zip(&reference) {
                    assert_eq!(
                        s.keep, r.keep,
                        "keep mismatch: rule {rule:?} shards {k} m {m}"
                    );
                    assert_eq!(
                        s.bounds, r.bounds,
                        "bounds not bit-identical: rule {rule:?} shards {k}"
                    );
                    assert_eq!(s.lambda1, r.lambda1);
                    assert_eq!(s.lambda2, r.lambda2);
                }
            }
        }
    }
}

/// Requesting more shards than features clamps instead of panicking or
/// emitting empty shards, and stays bit-identical.
#[test]
fn shard_count_exceeding_features_clamps() {
    let p = Problem::from_dataset(&SynthSpec::dense(30, 7, 913).generate());
    let theta1 = p.theta_at_lambda_max().theta();
    let l1 = p.lambda_max();
    let sc = ShardedScreener::build(&p, 50, 2).unwrap();
    assert!(sc.num_shards() <= 7, "got {} shards for 7 features", sc.num_shards());
    assert!(sc.num_shards() >= 1);
    let reference = screen_multi_with(
        RuleKind::Paper,
        &p.x,
        &p.y,
        &theta1,
        l1,
        &[0.5 * l1],
        Some(p.cache()),
    )
    .unwrap();
    let sharded =
        sc.screen_multi(RuleKind::Paper, &p.y, &theta1, l1, &[0.5 * l1]).unwrap();
    assert_eq!(sharded[0].keep, reference[0].keep);
    assert_eq!(sharded[0].bounds, reference[0].bounds);
}

fn req(c: &mut Client, fields: Vec<(&str, Json)>) -> Json {
    c.request(&Json::obj(fields)).unwrap()
}

/// End-to-end over the wire: a sharded server screens identically to an
/// unsharded one, and `{"cmd":"stats"}` exposes the per-shard
/// kept/screened counters, the seconds histogram, and the shard-shape
/// gauges the tentpole promises.
#[test]
fn sharded_server_matches_unsharded_and_exports_shard_metrics() {
    let spec = SynthSpec::text(50, 150, 914);
    let p_sharded = Problem::from_dataset(&spec.generate());
    let p_plain = Problem::from_dataset(&spec.generate());

    let sharded = ScreeningServer::start(
        p_sharded,
        ServerConfig { shards: 3, ..ServerConfig::default() },
    )
    .unwrap();
    let plain = ScreeningServer::start(p_plain, ServerConfig::default()).unwrap();

    let mut cs = Client::connect(sharded.addr).unwrap();
    let mut cp = Client::connect(plain.addr).unwrap();
    let info = req(&mut cs, vec![("cmd", Json::Str("info".into()))]);
    let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();

    for frac in [0.8, 0.5, 0.25] {
        let fields = || {
            vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(frac * lmax)),
                ("indices", Json::Bool(true)),
            ]
        };
        let rs = req(&mut cs, fields());
        let rp = req(&mut cp, fields());
        assert_eq!(rs.get("ok"), Some(&Json::Bool(true)), "{rs:?}");
        assert_eq!(rs.get("kept"), rp.get("kept"), "frac {frac}");
        assert_eq!(rs.get("screened"), rp.get("screened"), "frac {frac}");
        assert_eq!(rs.get("indices"), rp.get("indices"), "frac {frac}");
    }

    let stats = req(&mut cs, vec![("cmd", Json::Str("stats".into()))]);
    let metrics = stats.get("metrics").unwrap();
    let counters = metrics.get("counters").unwrap();
    let gauges = metrics.get("gauges").unwrap();
    let hists = metrics.get("histograms").unwrap();
    // Shard shape gauges (registered at build).
    assert!(
        gauges.get("coordinator.shard.count").unwrap().as_f64().unwrap() >= 2.0,
        "{gauges:?}"
    );
    assert!(
        gauges.get("coordinator.shard.imbalance").unwrap().as_f64().unwrap() >= 1.0
    );
    // Per-shard sweep metrics: every live shard screened 150 features
    // over 3 requests, so kept + screened must be positive.
    let shard_count =
        gauges.get("coordinator.shard.count").unwrap().as_f64().unwrap() as usize;
    for k in 0..shard_count {
        let kept = counters
            .get(&format!("coordinator.shard.{k}.kept"))
            .unwrap_or_else(|| panic!("missing shard {k} kept counter"))
            .as_f64()
            .unwrap();
        let screened = counters
            .get(&format!("coordinator.shard.{k}.screened"))
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(kept + screened > 0.0, "shard {k} never swept");
        let secs = hists.get(&format!("coordinator.shard.{k}.seconds")).unwrap();
        assert!(
            secs.get("count").unwrap().as_f64().unwrap() >= 3.0,
            "shard {k} seconds histogram undercounts: {secs:?}"
        );
        assert!(gauges.get(&format!("coordinator.shard.{k}.nnz")).is_some());
    }
    // The sharded sweep reports into the per-rule screening telemetry
    // exactly like seq/batch/par sweeps do (default server rule: paper).
    assert!(
        counters.get("screening.paper.sweeps").unwrap().as_f64().unwrap() >= 1.0,
        "{counters:?}"
    );

    sharded.shutdown();
    plain.shutdown();
}
