//! Integration: the PJRT (AOT) execution path vs the native rust
//! implementations. Requires `make artifacts` (skips gracefully if the
//! artifact dir is absent so `cargo test` works on a fresh checkout).

use svmscreen::data::synth::SynthSpec;
use svmscreen::data::FeatureMatrix;
use svmscreen::runtime::{screen_all_pjrt, PjrtEngine, PjrtScreenOptions};
use svmscreen::screening::rule::{screen_all, RuleKind};
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};
use svmscreen::svm::problem::Problem;

fn engine() -> Option<PjrtEngine> {
    let dir = PjrtEngine::default_dir();
    if !dir.exists() {
        eprintln!("skipping: artifact dir {dir:?} missing (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::load(dir).expect("engine load"))
}

#[test]
fn engine_discovers_artifacts() {
    let Some(engine) = engine() else { return };
    assert!(engine.screen_exe_for(100).is_some(), "{engine:?}");
    assert!(engine.screen_exe_for(1000).is_some());
    assert!(engine.screen_exe_for(100_000).is_none());
    assert!(engine.grad_exe_for(200, 400).is_some());
}

#[test]
fn pjrt_screening_matches_native_decisions() {
    let Some(engine) = engine() else { return };
    for spec in [SynthSpec::dense(120, 300, 301), SynthSpec::text(200, 600, 302)] {
        let p = Problem::from_dataset(&spec.generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        for frac in [0.9, 0.6, 0.3] {
            let l2 = frac * l1;
            let native = screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, l1, l2).unwrap();
            let pjrt = screen_all_pjrt(
                &engine,
                &p.x,
                &p.y,
                &theta1,
                l1,
                l2,
                &PjrtScreenOptions::default(),
            )
            .unwrap();
            assert_eq!(pjrt.keep.len(), native.keep.len());
            // Bounds agree to f32 accuracy.
            let mut max_dev = 0.0f64;
            for j in 0..p.m() {
                let d = (pjrt.bounds[j] - native.bounds[j]).abs()
                    / (1.0 + native.bounds[j].abs());
                max_dev = max_dev.max(d);
            }
            assert!(max_dev < 1e-3, "{} frac={frac}: max dev {max_dev}", p.name);
            // Decisions: pjrt (with keep margin) must keep a superset of
            // what native keeps minus borderline cases; exact agreement
            // away from the threshold.
            for j in 0..p.m() {
                if (native.bounds[j] - 1.0).abs() > 5e-3 {
                    assert_eq!(
                        pjrt.keep[j], native.keep[j],
                        "{} frac={frac} feature {j}: bound {}",
                        p.name, native.bounds[j]
                    );
                }
                if native.keep[j] {
                    assert!(pjrt.keep[j], "pjrt dropped a native-kept feature");
                }
            }
        }
    }
}

#[test]
fn pjrt_screening_is_safe_end_to_end() {
    let Some(engine) = engine() else { return };
    let p = Problem::from_dataset(&SynthSpec::text(150, 400, 303).generate());
    let theta1 = p.theta_at_lambda_max().theta();
    let l1 = p.lambda_max();
    let l2 = 0.5 * l1;
    let rep = screen_all_pjrt(
        &engine,
        &p.x,
        &p.y,
        &theta1,
        l1,
        l2,
        &PjrtScreenOptions::default(),
    )
    .unwrap();
    let exact = solve(SolverKind::Cd, &p.x, &p.y, l2, None, &SolveOptions::precise()).unwrap();
    assert!(exact.converged);
    for j in 0..p.m() {
        if !rep.keep[j] {
            assert!(
                exact.w[j].abs() < 1e-7,
                "pjrt screened active feature {j} (w = {})",
                exact.w[j]
            );
        }
    }
    assert!(rep.n_screened() > 0, "screening should fire");
}

#[test]
fn pjrt_grad_matches_native() {
    let Some(engine) = engine() else { return };
    let ds = SynthSpec::dense(200, 400, 304).generate();
    let exe = engine.grad_exe_for(200, 400).expect("grad artifact");
    let (n_pad, m_pad) = (exe.n, exe.m);
    // Pack x row-major (n_pad, m_pad), f32.
    let mut x = vec![0.0f32; n_pad * m_pad];
    for j in 0..400 {
        ds.x.col_visit(j, &mut |i, v| x[i * m_pad + j] = v as f32);
    }
    let mut y = vec![0.0f32; n_pad];
    for i in 0..200 {
        y[i] = ds.y[i] as f32;
    }
    // Padded samples have y=0 -> xi = max(1-0,0) = 1 contributes to loss
    // and gb! Guard: padded y=0 gives xi=1, u=0 (xi*y=0) so gw/gb are
    // unaffected; loss is offset by a constant 0.5*pad. Account for it.
    let mut w = vec![0.0f32; m_pad];
    let mut rng = svmscreen::data::synth::Pcg32::seeded(305);
    for j in 0..400 {
        w[j] = (0.1 * rng.gaussian()) as f32;
    }
    let b = 0.15f32;
    let (gw, gb, loss) = exe.run(&x, &y, &w, b).unwrap();

    let w64: Vec<f64> = w[..400].iter().map(|v| *v as f64).collect();
    let mar = svmscreen::svm::objective::margins(&ds.x, &ds.y, &w64, b as f64);
    let (gw_native, gb_native) =
        svmscreen::svm::objective::primal_gradient(&ds.x, &ds.y, &mar);
    for j in 0..400 {
        let d = (gw[j] as f64 - gw_native[j]).abs() / (1.0 + gw_native[j].abs());
        assert!(d < 1e-4, "gw[{j}]: {} vs {}", gw[j], gw_native[j]);
    }
    assert!((gb as f64 - gb_native).abs() / (1.0 + gb_native.abs()) < 1e-4);
    let pad_offset = 0.5 * (n_pad - 200) as f64; // padded rows: xi=1 each
    assert!(
        ((loss as f64 - pad_offset) - mar.loss()).abs() / (1.0 + mar.loss()) < 1e-4,
        "loss {} (pad-adjusted {}) vs {}",
        loss,
        loss as f64 - pad_offset,
        mar.loss()
    );
}
