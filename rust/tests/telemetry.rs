//! End-to-end telemetry: the acceptance workload for the in-tree
//! observability layer.
//!
//! Drives a solve + screen workload through the TCP service, then
//! checks the `{"cmd":"stats"}` round-trip reports nonzero request
//! counters and latency percentiles — the live-stats surface the
//! server exposes over the wire. Also hammers the global registry from
//! the coordinator's own thread pool to prove the lock-cheap counters
//! aggregate correctly under contention.

use svmscreen::coordinator::pool::parallel_map;
use svmscreen::coordinator::protocol::Json;
use svmscreen::coordinator::server::{Client, ScreeningServer, ServerConfig};
use svmscreen::data::synth::SynthSpec;
use svmscreen::svm::problem::Problem;

fn cmd(name: &str) -> Json {
    Json::obj(vec![("cmd", Json::Str(name.into()))])
}

#[test]
fn stats_roundtrip_reports_live_workload() {
    let p = Problem::from_dataset(&SynthSpec::text(80, 300, 301).generate());
    let server = ScreeningServer::start(p, ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();

    let info = c.request(&cmd("info")).unwrap();
    let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();

    // Workload: one solve (moves the dual point), several screens.
    let sol = c
        .request(&Json::obj(vec![
            ("cmd", Json::Str("solve".into())),
            ("lambda", Json::Num(0.7 * lmax)),
        ]))
        .unwrap();
    assert_eq!(sol.get("ok"), Some(&Json::Bool(true)), "{sol:?}");
    for frac in [0.6, 0.5, 0.4] {
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(frac * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
    }

    let stats = c.request(&cmd("stats")).unwrap();
    assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats:?}");

    // Server-local counters: exactly this workload.
    assert_eq!(stats.get("solves").unwrap().as_f64(), Some(1.0));
    assert_eq!(stats.get("screens").unwrap().as_f64(), Some(3.0));
    assert!(stats.get("batches").unwrap().as_f64().unwrap() >= 1.0);

    // Registry metrics: nonzero request counters...
    let metrics = stats.get("metrics").unwrap();
    let counters = metrics.get("counters").unwrap();
    for key in ["server.requests", "server.connections", "server.batches"] {
        let v = counters.get(key).unwrap().as_f64().unwrap();
        assert!(v >= 1.0, "{key} = {v}");
    }
    // ...and latency percentiles from real observations. The registry
    // is process-global (other tests may add to it), so bounds only.
    let hists = metrics.get("histograms").unwrap();
    for key in ["server.screen.seconds", "server.solve.seconds"] {
        let h = hists.get(key).unwrap();
        let count = h.get("count").unwrap().as_f64().unwrap();
        assert!(count >= 1.0, "{key} count = {count}");
        let p50 = h.get("p50").unwrap().as_f64().unwrap();
        let p99 = h.get("p99").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0, "{key} p50 = {p50}");
        assert!(p99 >= p50, "{key} p99 {p99} < p50 {p50}");
    }
    // Solver/screening layers fired underneath the service.
    assert!(counters.get("solver.cd.solves").unwrap().as_f64().unwrap() >= 1.0);
    assert!(
        counters
            .get("screening.paper.sweeps")
            .unwrap()
            .as_f64()
            .unwrap()
            >= 1.0
    );

    server.shutdown();
}

#[test]
fn registry_counters_sum_under_pool_contention() {
    let tele = svmscreen::telemetry::global();
    let before = tele.counter("test.pool.contention").get();
    let items: Vec<usize> = (0..64).collect();
    let adds = parallel_map(&items, 8, |&i| {
        let c = svmscreen::telemetry::global().counter("test.pool.contention");
        for _ in 0..500 {
            c.inc();
        }
        c.add(i as u64);
        500 + i as u64
    });
    let expected: u64 = adds.iter().sum();
    let after = tele.counter("test.pool.contention").get();
    assert_eq!(after - before, expected);

    // Histograms under the same contention: every record lands.
    let hist_before = tele.histogram("test.pool.hist").count();
    parallel_map(&items, 8, |&i| {
        svmscreen::telemetry::global()
            .histogram("test.pool.hist")
            .record(1e-6 * (i + 1) as f64);
    });
    let hist_after = tele.histogram("test.pool.hist").count();
    assert_eq!(hist_after - hist_before, 64);
}
