//! Cross-module integration: full paths, safety sweeps, warm starts and
//! the reduced-problem equivalence — the system-level guarantees.

use svmscreen::data::synth::SynthSpec;
use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::screening::rule::RuleKind;
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};
use svmscreen::svm::problem::Problem;

/// The contract of safe screening: identical path objectives for every
/// safe rule, on every dataset family, with both solvers.
#[test]
fn all_safe_rules_preserve_the_path() {
    let specs = [
        SynthSpec::dense(60, 50, 401),
        SynthSpec::text(80, 200, 402),
        SynthSpec::corr(50, 40, 403),
    ];
    for spec in specs {
        let p = Problem::from_dataset(&spec.generate());
        let grid = geometric(p.lambda_max(), 0.1, 6).unwrap();
        let opts = SolveOptions { tol: 1e-8, max_iter: 30000, ..Default::default() };
        let baseline = run_path(
            &p,
            &grid,
            &PathConfig { rule: RuleKind::None, solve: opts, ..Default::default() },
        )
        .unwrap();
        for rule in RuleKind::SAFE {
            let run = run_path(
                &p,
                &grid,
                &PathConfig { rule, solve: opts, ..Default::default() },
            )
            .unwrap();
            for k in 0..grid.len() {
                let o_base = svmscreen::svm::objective::primal_objective(
                    &p.x,
                    &p.y,
                    &baseline.weights[k],
                    baseline.biases[k],
                    grid[k],
                );
                let o_rule = svmscreen::svm::objective::primal_objective(
                    &p.x,
                    &p.y,
                    &run.weights[k],
                    run.biases[k],
                    grid[k],
                );
                let dev = (o_base - o_rule).abs() / o_base.max(1e-12);
                assert!(
                    dev < 1e-5,
                    "{} rule {} step {k}: objective dev {dev}",
                    p.name,
                    rule.name()
                );
            }
        }
    }
}

/// Screening power ordering along a real path: paper >= ball >= sphere.
#[test]
fn rule_power_ordering_holds_on_paths() {
    let p = Problem::from_dataset(&SynthSpec::text(80, 300, 405).generate());
    let grid = geometric(p.lambda_max(), 0.1, 8).unwrap();
    let mut rejections = Vec::new();
    for rule in [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere] {
        let run =
            run_path(&p, &grid, &PathConfig { rule, ..Default::default() }).unwrap();
        rejections.push(run.totals().mean_rejection);
    }
    assert!(
        rejections[0] >= rejections[1] - 1e-12,
        "paper {} < ball {}",
        rejections[0],
        rejections[1]
    );
    assert!(
        rejections[1] >= rejections[2] - 1e-12,
        "ball {} < sphere {}",
        rejections[1],
        rejections[2]
    );
}

/// Both solvers agree along a screened path.
#[test]
fn solvers_agree_on_screened_path() {
    let p = Problem::from_dataset(&SynthSpec::dense(60, 40, 407).generate());
    let grid = geometric(p.lambda_max(), 0.2, 5).unwrap();
    let opts = SolveOptions { tol: 1e-7, max_iter: 50000, ..Default::default() };
    let cd = run_path(
        &p,
        &grid,
        &PathConfig { solver: SolverKind::Cd, solve: opts, ..Default::default() },
    )
    .unwrap();
    let fista = run_path(
        &p,
        &grid,
        &PathConfig { solver: SolverKind::Fista, solve: opts, ..Default::default() },
    )
    .unwrap();
    for k in 0..grid.len() {
        let o1 = svmscreen::svm::objective::primal_objective(
            &p.x, &p.y, &cd.weights[k], cd.biases[k], grid[k],
        );
        let o2 = svmscreen::svm::objective::primal_objective(
            &p.x, &p.y, &fista.weights[k], fista.biases[k], grid[k],
        );
        assert!((o1 - o2).abs() / o1.max(1e-12) < 1e-4, "step {k}: {o1} vs {o2}");
    }
}

/// Sparsity is monotone-ish along the path and the active sets grow.
#[test]
fn path_active_sets_grow_sensibly() {
    let p = Problem::from_dataset(&SynthSpec::text(100, 400, 409).generate());
    let grid = geometric(p.lambda_max(), 0.05, 10).unwrap();
    let run = run_path(&p, &grid, &PathConfig::default()).unwrap();
    let first_nnz = run.steps.first().unwrap().nnz;
    let last_nnz = run.steps.last().unwrap().nnz;
    assert!(first_nnz < last_nnz, "nnz {first_nnz} -> {last_nnz}");
    // kept never drops below nnz (safe screening keeps all active).
    for s in &run.steps {
        assert!(s.kept >= s.nnz, "kept {} < nnz {}", s.kept, s.nnz);
    }
}

/// Recovery sanity on planted data: with enough signal the path finds
/// mostly-true features at moderate lambda.
#[test]
fn planted_support_partially_recovered() {
    let ds = SynthSpec::dense(200, 50, 411).generate();
    let truth: std::collections::HashSet<usize> =
        ds.true_support.clone().unwrap().into_iter().collect();
    let p = Problem::from_dataset(&ds);
    let rep = solve(
        SolverKind::Cd,
        &p.x,
        &p.y,
        0.2 * p.lambda_max(),
        None,
        &SolveOptions::default(),
    )
    .unwrap();
    let active = rep.active_set();
    let hits = active.iter().filter(|j| truth.contains(j)).count();
    assert!(
        hits * 2 >= truth.len(),
        "recovered only {hits} of {} planted features (active: {})",
        truth.len(),
        active.len()
    );
}
