//! Path-wide feature-cache guarantees: cached screening is bit-identical
//! to the uncached `col_dot4` path, incremental reduced problems match
//! from-scratch gathers byte-for-byte, and the reuse telemetry lands in
//! the global registry.

use svmscreen::coordinator::parallel::screen_all_parallel_with;
use svmscreen::data::synth::SynthSpec;
use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::screening::rule::{screen_all, screen_all_with, RuleKind};
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};
use svmscreen::solver::reduced::ReducedProblem;
use svmscreen::svm::problem::Problem;

const RULES: [RuleKind; 4] =
    [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong];

/// Cached stats (and the block-parallel executor at any worker count)
/// must reproduce the uncached sequential sweep to the last bit — same
/// keep decisions AND same bound values.
#[test]
fn cached_screening_bit_identical_to_uncached() {
    let specs = [SynthSpec::dense(50, 80, 901), SynthSpec::text(70, 300, 902)];
    for spec in specs {
        let p = Problem::from_dataset(&spec.generate());
        let lmax = p.lambda_max();
        let cache = p.cache();

        // Two dual points: the closed form at lambda_max and a solved
        // mid-path point (the realistic warm-started case).
        let rep = solve(
            SolverKind::Cd,
            &p.x,
            &p.y,
            0.5 * lmax,
            None,
            &SolveOptions { tol: 1e-7, ..Default::default() },
        )
        .unwrap();
        let theta_mid =
            svmscreen::svm::dual::theta_from_primal(&p.x, &p.y, &rep.w, rep.b, 0.5 * lmax);
        let points = [(lmax, p.theta_at_lambda_max().theta()), (0.5 * lmax, theta_mid)];

        for (lambda1, theta1) in &points {
            let lambda1 = *lambda1;
            for rule in RULES {
                for frac in [0.9, 0.5, 0.2] {
                    let lambda2 = frac * lambda1;
                    let base =
                        screen_all(rule, &p.x, &p.y, theta1, lambda1, lambda2).unwrap();
                    let cached = screen_all_with(
                        rule,
                        &p.x,
                        &p.y,
                        theta1,
                        lambda1,
                        lambda2,
                        Some(cache),
                    )
                    .unwrap();
                    assert_eq!(base.keep, cached.keep, "{} keep {rule:?} {frac}", p.name);
                    assert_eq!(
                        base.bounds, cached.bounds,
                        "{} bounds {rule:?} {frac}",
                        p.name
                    );
                    for workers in [1, 4] {
                        let par = screen_all_parallel_with(
                            rule,
                            &p.x,
                            &p.y,
                            theta1,
                            lambda1,
                            lambda2,
                            workers,
                            Some(cache),
                        )
                        .unwrap();
                        assert_eq!(
                            base.keep, par.keep,
                            "{} parallel({workers}) keep {rule:?} {frac}",
                            p.name
                        );
                        assert_eq!(
                            base.bounds, par.bounds,
                            "{} parallel({workers}) bounds {rule:?} {frac}",
                            p.name
                        );
                    }
                }
            }
        }
    }
}

/// Sub-selecting from the previous reduced matrix must produce the same
/// columns, the same bytes, and the same solve as a from-scratch gather.
#[test]
fn incremental_reduction_matches_scratch() {
    let p = Problem::from_dataset(&SynthSpec::text(100, 200, 903).generate());
    let cache = p.cache();
    let lambda = 0.3 * p.lambda_max();
    let opts = SolveOptions { tol: 1e-7, ..Default::default() };

    let s1: Vec<usize> = (0..200).step_by(2).collect();
    let r1 = ReducedProblem::build_with(&p.x, s1, Some(cache), 2).unwrap();

    // Subset of the previous kept set: must reuse.
    let s2: Vec<usize> = (0..200).step_by(4).collect();
    let (r2, reused) =
        ReducedProblem::build_incremental(&r1, &p.x, s2.clone(), Some(cache), 2).unwrap();
    assert!(reused, "subset kept set must take the incremental path");
    let scratch = ReducedProblem::build_with(&p.x, s2, Some(cache), 1).unwrap();
    assert_eq!(r2.cols, scratch.cols);
    assert_eq!(r2.x, scratch.x, "sub-selected matrix must be byte-identical");
    assert_eq!(r2.cache, scratch.cache, "remapped cache must match");
    let a = r2.solve(SolverKind::Cd, &p.y, lambda, None, &opts).unwrap();
    let b = scratch.solve(SolverKind::Cd, &p.y, lambda, None, &opts).unwrap();
    assert_eq!(a.w, b.w, "identical inputs must give identical solutions");
    assert_eq!(a.b, b.b);

    // Not a subset (col 1 was never in r1): falls back to a full gather.
    let s3 = vec![1usize, 4, 8];
    let (r3, reused3) =
        ReducedProblem::build_incremental(&r1, &p.x, s3.clone(), Some(cache), 2).unwrap();
    assert!(!reused3, "non-subset must fall back to a full gather");
    let scratch3 = ReducedProblem::build_with(&p.x, s3, Some(cache), 1).unwrap();
    assert_eq!(r3.cols, scratch3.cols);
    assert_eq!(r3.x, scratch3.x);
}

/// The full path with incremental reuse enabled is exactly the path with
/// it disabled: same kept sets, same weights, same biases, bit for bit.
#[test]
fn incremental_path_identical_to_scratch_path() {
    let p = Problem::from_dataset(&SynthSpec::text(80, 300, 905).generate());
    let grid = geometric(p.lambda_max(), 0.05, 10).unwrap();
    let inc = run_path(
        &p,
        &grid,
        &PathConfig { incremental: true, workers: 2, ..Default::default() },
    )
    .unwrap();
    let scr = run_path(
        &p,
        &grid,
        &PathConfig { incremental: false, workers: 1, ..Default::default() },
    )
    .unwrap();
    assert_eq!(inc.steps.len(), scr.steps.len());
    for k in 0..grid.len() {
        assert_eq!(inc.steps[k].kept, scr.steps[k].kept, "kept set size step {k}");
        assert_eq!(inc.weights[k], scr.weights[k], "weights step {k}");
        assert_eq!(inc.biases[k], scr.biases[k], "bias step {k}");
    }
}

/// The parallel executor must feed the same telemetry stream as the
/// sequential sweep (`screening.<rule>.sweeps` et al.).
#[test]
fn parallel_screen_records_sweep_telemetry() {
    let p = Problem::from_dataset(&SynthSpec::dense(40, 60, 907).generate());
    let lmax = p.lambda_max();
    let theta = p.theta_at_lambda_max().theta();
    let sweeps = svmscreen::telemetry::global().counter("screening.sphere.sweeps");
    let before = sweeps.get();
    screen_all_parallel_with(
        RuleKind::Sphere,
        &p.x,
        &p.y,
        &theta,
        lmax,
        0.5 * lmax,
        2,
        Some(p.cache()),
    )
    .unwrap();
    assert!(sweeps.get() >= before + 1, "parallel sweep must be counted");
}

/// A path run registers the cache-reuse metrics and exercises at least
/// one reduced gather.
#[test]
fn path_run_registers_cache_metrics() {
    let p = Problem::from_dataset(&SynthSpec::text(60, 250, 909).generate());
    let grid = geometric(p.lambda_max(), 0.1, 6).unwrap();
    run_path(&p, &grid, &PathConfig::default()).unwrap();
    let snap = svmscreen::telemetry::global().snapshot();
    for key in ["path.cache.hits", "path.cache.misses", "path.gather_bytes"] {
        assert!(snap.counters.contains_key(key), "missing counter {key}");
    }
    assert!(
        snap.histograms.contains_key("path.step.gather_seconds"),
        "missing gather histogram"
    );
    let hits = snap.counters["path.cache.hits"];
    let misses = snap.counters["path.cache.misses"];
    assert!(hits + misses >= 1, "path must build at least one reduced problem");
    assert!(snap.counters["path.gather_bytes"] > 0, "gathered bytes must be metered");
}
