//! Diagnostics guarantees: the provenance ledger is purely
//! observational (ledger-on screening is bit-identical to ledger-off),
//! `explain`-style queries answer from a recorded path run, and an
//! injected solver stall produces a counted anomaly plus a warn
//! instant in the exported Chrome trace.

use std::sync::Mutex;
use svmscreen::coordinator::parallel::screen_all_parallel_with;
use svmscreen::data::synth::SynthSpec;
use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::screening::rule::RuleKind;
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};
use svmscreen::svm::problem::Problem;

/// The ledger is process-global; tests that toggle it must not
/// interleave (a poisoned lock just means another test failed — take
/// the guard anyway so its failure stays the primary signal).
static LEDGER_LOCK: Mutex<()> = Mutex::new(());

fn lock_ledger() -> std::sync::MutexGuard<'static, ()> {
    LEDGER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const RULES: [RuleKind; 4] =
    [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong];

/// Acceptance: ledger-enabled screening is bit-identical to ledger-off
/// — same keep decisions AND same bound bits — across rules, dense and
/// sparse panels, sequential and block-parallel sweeps.
#[test]
fn ledger_recording_is_bit_identical_to_off() {
    let _guard = lock_ledger();
    let ledger = svmscreen::diag::ledger::global();
    let specs = [SynthSpec::dense(50, 80, 1301), SynthSpec::text(70, 300, 1302)];
    for spec in specs {
        let p = Problem::from_dataset(&spec.generate());
        let lmax = p.lambda_max();
        let theta1 = p.theta_at_lambda_max().theta();
        for rule in RULES {
            for workers in [1, 4] {
                ledger.set_enabled(false);
                let off = screen_all_parallel_with(
                    rule, &p.x, &p.y, &theta1, lmax, 0.5 * lmax, workers, None,
                )
                .unwrap();
                ledger.set_enabled(true);
                let on = screen_all_parallel_with(
                    rule, &p.x, &p.y, &theta1, lmax, 0.5 * lmax, workers, None,
                )
                .unwrap();
                assert_eq!(off.keep, on.keep, "{rule:?} workers={workers}");
                assert_eq!(off.bounds.len(), on.bounds.len());
                for (j, (a, b)) in off.bounds.iter().zip(&on.bounds).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{rule:?} workers={workers} bound[{j}]: {a} vs {b}"
                    );
                }
            }
        }
    }
    ledger.set_enabled(false);
    ledger.clear();
}

/// Verdicts faithfully mirror the sweep: one per feature, margins are
/// bound − threshold, near-misses respect the configured epsilon, and
/// the export round-trips through JSONL.
#[test]
fn ledger_verdicts_and_export_roundtrip() {
    let _guard = lock_ledger();
    let ledger = svmscreen::diag::ledger::global();
    ledger.clear();
    ledger.set_enabled(true);
    ledger.set_near_miss_eps(0.5);

    let p = Problem::from_dataset(&SynthSpec::text(60, 200, 1303).generate());
    let lmax = p.lambda_max();
    let theta1 = p.theta_at_lambda_max().theta();
    let rep =
        screen_all_parallel_with(RuleKind::Paper, &p.x, &p.y, &theta1, lmax, 0.6 * lmax, 1, None)
            .unwrap();

    let verdicts = ledger.snapshot();
    assert_eq!(verdicts.len(), 200, "one verdict per feature");
    for v in &verdicts {
        assert_eq!(v.rule, "paper");
        assert_eq!(v.kept, rep.keep[v.feature]);
        if v.bound.is_finite() {
            assert_eq!(v.margin, v.bound - v.threshold);
            assert_eq!(v.near_miss, v.margin.abs() < 0.5);
        }
    }
    let near = ledger.near_misses();
    assert!(!near.is_empty(), "eps=0.5 must flag some near-misses");
    // Sorted closest-call first.
    for pair in near.windows(2) {
        assert!(pair[0].margin.abs() <= pair[1].margin.abs());
    }
    let top = ledger.top_near_misses(3);
    assert_eq!(top.len(), 3.min(near.len()));

    let dir = std::env::temp_dir().join("svmscreen_diag_it_export");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("verdicts.jsonl");
    svmscreen::report::diag::write_jsonl(&path, &near).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), near.len());
    assert!(text.lines().all(|l| l.starts_with('{') && l.contains("\"margin\"")));
    let _ = std::fs::remove_dir_all(&dir);

    ledger.set_enabled(false);
    ledger.set_near_miss_eps(svmscreen::diag::ledger::DEFAULT_NEAR_MISS_EPS);
    ledger.clear();
}

/// The `explain` flow: a recorded path run answers a per-feature query
/// — every step's verdict for that feature, in sweep order.
#[test]
fn explain_query_answers_from_a_path_run() {
    let _guard = lock_ledger();
    let ledger = svmscreen::diag::ledger::global();
    ledger.clear();
    ledger.set_enabled(true);

    let p = Problem::from_dataset(&SynthSpec::dense(40, 30, 1304).generate());
    let grid = geometric(p.lambda_max(), 0.2, 5).unwrap();
    let report = run_path(&p, &grid, &PathConfig::default()).unwrap();
    assert_eq!(report.steps.len(), 5);

    let summary = ledger.summary();
    assert!(summary.enabled);
    assert!(
        summary.recorded >= (5 * 30) as u64,
        "5 sweeps x 30 features, got {}",
        summary.recorded
    );
    for j in [0usize, 7, 29] {
        let history = ledger.feature_history(j);
        assert!(!history.is_empty(), "feature {j} must have verdicts");
        assert!(history.iter().all(|v| v.feature == j && v.rule == "paper"));
        // Sweep order is chronological and the targets come off the grid.
        for pair in history.windows(2) {
            assert!(pair[0].sweep <= pair[1].sweep);
        }
        for v in &history {
            assert!(
                grid.iter().any(|&lam| lam.to_bits() == v.lambda2.to_bits()),
                "lambda2 {} not on the grid",
                v.lambda2
            );
        }
    }
    // Per-step near-miss counts surfaced in the path report.
    assert!(report.steps.iter().all(|s| s.near_miss <= 30));

    ledger.set_enabled(false);
    ledger.clear();
}

/// Acceptance: an injected stall (tolerance far below the numerical
/// floor, gap checked every step) produces counted solver anomalies, a
/// `solver.anomalies` increment, and a `solver.anomaly` warn instant
/// that survives into the Chrome trace export.
#[test]
fn injected_stall_is_counted_and_traced() {
    // Warn instants only mirror into the ring when warn is enabled.
    svmscreen::telemetry::init_from_env();
    svmscreen::telemetry::set_stderr_level(Some(svmscreen::telemetry::Level::Warn));

    let before = *svmscreen::telemetry::global()
        .snapshot()
        .counters
        .get("solver.anomalies")
        .unwrap_or(&0);

    let p = Problem::from_dataset(&SynthSpec::dense(30, 10, 1305).generate());
    let opts = SolveOptions {
        tol: 1e-18, // unreachable: rel_gap plateaus at the numerical floor
        max_iter: 300,
        gap_check_every: 1,
        ..Default::default()
    };
    let rep =
        solve(SolverKind::Fista, &p.x, &p.y, 0.5 * p.lambda_max(), None, &opts).unwrap();
    assert!(!rep.converged, "tol 1e-18 must be unreachable");
    assert!(rep.anomalies > 0, "plateaued solve must flag a stall");

    let after = *svmscreen::telemetry::global()
        .snapshot()
        .counters
        .get("solver.anomalies")
        .unwrap_or(&0);
    assert!(
        after >= before + rep.anomalies as u64,
        "counter moved {before} -> {after}, expected +{}",
        rep.anomalies
    );

    // The warn instant lands in the trace ring and the Chrome export.
    let records = svmscreen::telemetry::trace::recorder().snapshot();
    assert!(
        records.iter().any(|r| r.name == "solver.anomaly"),
        "expected a solver.anomaly instant among {} records",
        records.len()
    );
    let doc = svmscreen::telemetry::trace::chrome_trace(&records).encode();
    assert!(doc.contains("solver.anomaly"), "instant missing from Chrome doc");
    let dir = std::env::temp_dir().join("svmscreen_diag_it_trace");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    std::fs::write(&path, &doc).unwrap();
    assert!(std::fs::read_to_string(&path).unwrap().contains("solver.anomaly"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Every solve archives a convergence summary into the global log.
#[test]
fn solves_archive_convergence_summaries() {
    let p = Problem::from_dataset(&SynthSpec::dense(30, 12, 1306).generate());
    let lambda = 0.437_711 * p.lambda_max();
    let opts = SolveOptions { tol: 1e-6, ..Default::default() };
    let cd = solve(SolverKind::Cd, &p.x, &p.y, lambda, None, &opts).unwrap();
    let fi = solve(SolverKind::Fista, &p.x, &p.y, lambda, None, &opts).unwrap();
    assert!(cd.converged && fi.converged);

    let log = svmscreen::diag::convergence::log_snapshot();
    // Find our solves by exact lambda (the log is process-global).
    let cd_entry = log
        .iter()
        .find(|s| s.solver == "cd" && s.lambda.to_bits() == lambda.to_bits())
        .expect("cd summary archived");
    assert!(cd_entry.converged);
    assert_eq!(cd_entry.iterations, cd.iterations);
    let fi_entry = log
        .iter()
        .find(|s| s.solver == "fista" && s.lambda.to_bits() == lambda.to_bits())
        .expect("fista summary archived");
    assert!(fi_entry.converged);
    assert!(fi_entry.checks > 0);
}
