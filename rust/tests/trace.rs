//! End-to-end trace recorder: the acceptance workload for the
//! Chrome-trace export and safety-audit surfaces.
//!
//! * a real path run leaves spans in the global ring, and the exported
//!   file is a well-formed Chrome trace-event document (what Perfetto
//!   and `chrome://tracing` load);
//! * the ring stays bounded and counts evictions under concurrent
//!   writers;
//! * `{"cmd":"trace"}` drains the ring over the wire;
//! * safety-audit mode reports zero violations for a safe rule on
//!   synthetic data, and flags a forged report's KKT violation.
//!
//! The span/trace ring is process-global, and tests in this binary run
//! concurrently — each global-ring assertion retries, since any sibling
//! may drain the ring between a record and its check.

use svmscreen::coordinator::protocol::{parse, Json};
use svmscreen::coordinator::server::{Client, ScreeningServer, ServerConfig};
use svmscreen::data::synth::SynthSpec;
use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::screening::rule::{screen_all, RuleKind};
use svmscreen::screening::variants::audit_screen;
use svmscreen::svm::problem::Problem;
use svmscreen::telemetry::trace::{self, RecordKind, TraceRecord, TraceRing};

fn small_path() {
    let p = Problem::from_dataset(&SynthSpec::text(60, 240, 71).generate());
    let grid = geometric(p.lambda_max(), 0.3, 4).unwrap();
    run_path(&p, &grid, &PathConfig::default()).expect("path");
}

#[test]
fn chrome_trace_file_is_wellformed() {
    let dir = std::env::temp_dir().join(format!("pallas_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let path_s = path.to_str().unwrap();

    // A sibling test may drain the global ring between our workload and
    // the export; retry until the written file carries records.
    let mut n = 0usize;
    for _ in 0..50 {
        small_path();
        n = trace::write_chrome_file(path_s).expect("write trace");
        if n > 0 {
            break;
        }
    }
    assert!(n > 0, "no trace records after 50 attempts");

    let text = std::fs::read_to_string(&path).unwrap();
    let doc = parse(&text).expect("trace file must be valid JSON");
    assert_eq!(
        doc.get("displayTimeUnit").unwrap().as_str(),
        Some("ms"),
        "{text:.100}"
    );
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), n);
    for ev in events {
        // Chrome trace-event required keys.
        assert!(ev.get("name").unwrap().as_str().is_some());
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(ev.get("ts").unwrap().as_f64().is_some());
        assert!(ev.get("pid").unwrap().as_f64().is_some());
        assert!(ev.get("tid").unwrap().as_f64().is_some());
        match ph {
            "X" => assert!(ev.get("dur").unwrap().as_f64().is_some()),
            "i" => assert_eq!(ev.get("s").unwrap().as_str(), Some("t")),
            other => panic!("unexpected phase {other:?}"),
        }
    }
    // The path workload's own spans are present.
    let names: Vec<&str> = events
        .iter()
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(
        names.iter().any(|n| n.starts_with("path.")),
        "no path.* span among {names:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ring_stays_bounded_under_concurrent_writers() {
    let ring = TraceRing::new(64);
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let ring = &ring;
            s.spawn(move || {
                for i in 0..100u64 {
                    ring.record(TraceRecord {
                        name: format!("load.t{t}"),
                        label: None,
                        kind: RecordKind::Span,
                        ts_us: i,
                        dur_us: 1,
                        tid: t,
                        depth: 0,
                    });
                }
            });
        }
    });
    // 800 records through a 64-slot ring: exactly capacity survive.
    assert_eq!(ring.len(), 64);
    assert_eq!(ring.dropped(), 800 - 64);
    let drained = ring.drain();
    assert_eq!(drained.len(), 64);
    assert_eq!(ring.dropped(), 0);
}

#[test]
fn trace_command_roundtrip_over_the_wire() {
    let p = Problem::from_dataset(&SynthSpec::text(60, 240, 72).generate());
    let server = ScreeningServer::start(p, ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    let info = c
        .request(&Json::obj(vec![("cmd", Json::Str("info".into()))]))
        .unwrap();
    let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();

    // The server closes its batch span before replying, so after an ok
    // screen reply the span is in the ring — unless a sibling test
    // drained it first. Retry the pair.
    let mut saw_batch_span = false;
    for _ in 0..50 {
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.5 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let tr = c
            .request(&Json::obj(vec![("cmd", Json::Str("trace".into()))]))
            .unwrap();
        assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr:?}");
        let records = tr.get("records").unwrap().as_arr().unwrap();
        let count = tr.get("count").unwrap().as_f64().unwrap() as usize;
        assert_eq!(records.len(), count);
        if records
            .iter()
            .any(|r| r.get("name").unwrap().as_str() == Some("server.batch"))
        {
            saw_batch_span = true;
            break;
        }
    }
    assert!(saw_batch_span, "server.batch span never drained over the wire");

    // chrome:true returns the loadable document instead of raw records.
    let rep = c
        .request(&Json::obj(vec![
            ("cmd", Json::Str("screen".into())),
            ("lambda2", Json::Num(0.4 * lmax)),
        ]))
        .unwrap();
    assert_eq!(rep.get("ok"), Some(&Json::Bool(true)));
    let tr = c
        .request(&Json::obj(vec![
            ("cmd", Json::Str("trace".into())),
            ("chrome", Json::Bool(true)),
        ]))
        .unwrap();
    assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr:?}");
    assert!(tr.get("records").is_none());
    assert!(tr.get("chrome").unwrap().get("traceEvents").unwrap().as_arr().is_some());
    server.shutdown();
}

#[test]
fn audit_mode_is_clean_on_synthetic_path() {
    let p = Problem::from_dataset(&SynthSpec::dense(60, 120, 73).generate());
    let grid = geometric(p.lambda_max(), 0.2, 5).unwrap();
    let cfg = PathConfig { audit: true, ..Default::default() };
    let rep = run_path(&p, &grid, &cfg).expect("path");
    for s in &rep.steps {
        assert_eq!(
            s.audit_violations,
            Some(0),
            "safe rule must audit clean at lambda_frac {}",
            s.lambda_frac
        );
    }
    // The audit registers the violation counter even when clean, so
    // "audited, found nothing" is visible in stats.
    let snap = svmscreen::telemetry::global().snapshot().to_json();
    assert!(
        snap.get("counters").unwrap().get("screening.violations").is_some(),
        "screening.violations missing from snapshot"
    );
}

#[test]
fn audit_flags_forged_screen_report() {
    let p = Problem::from_dataset(&SynthSpec::dense(50, 100, 74).generate());
    let lambda1 = p.lambda_max();
    let lambda2 = 0.3 * lambda1;
    let theta1 = p.theta_at_lambda_max().theta();
    let mut report =
        screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();

    // Solve honestly to find an active feature, then forge the report to
    // claim it was screened out and re-solve WITHOUT it — the audit must
    // catch the KKT violation the forged screening introduced.
    let opts = svmscreen::solver::api::SolveOptions::precise();
    let full = svmscreen::solver::api::solve(
        svmscreen::solver::api::SolverKind::Cd,
        &p.x,
        &p.y,
        lambda2,
        None,
        &opts,
    )
    .unwrap();
    let victim = (0..p.m())
        .max_by(|&a, &b| full.w[a].abs().partial_cmp(&full.w[b].abs()).unwrap())
        .unwrap();
    assert!(full.w[victim].abs() > 1e-6, "need an active feature");
    report.keep[victim] = false;

    let kept = report.kept_indices();
    let red = svmscreen::solver::reduced::ReducedProblem::build(&p.x, kept).unwrap();
    let sol = red
        .solve(svmscreen::solver::api::SolverKind::Cd, &p.y, lambda2, None, &opts)
        .unwrap();
    let audit = audit_screen(&p.x, &p.y, &report, &sol.w, sol.b, 1e-6);
    assert!(!audit.is_clean());
    assert!(
        audit.violations.iter().any(|v| v.feature == victim),
        "victim {victim} not among violations"
    );
}
