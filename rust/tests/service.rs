//! Integration: the screening service under concurrent load, exercising
//! the accept loop, handler pool, batcher and shutdown path together.

use std::time::Duration;
use svmscreen::coordinator::batcher::BatchPolicy;
use svmscreen::coordinator::protocol::Json;
use svmscreen::coordinator::server::{Client, ScreeningServer, ServerConfig};
use svmscreen::data::synth::SynthSpec;
use svmscreen::svm::problem::Problem;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::obj(pairs)
}

#[test]
fn full_session_lifecycle() {
    let p = Problem::from_dataset(&SynthSpec::text(80, 250, 501).generate());
    let lmax = p.lambda_max();
    let server = ScreeningServer::start(p, ServerConfig::default()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();

    // info -> solve -> screen at progressively smaller lambda
    let info = c.request(&obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
    assert_eq!(info.get("lambda1").unwrap().as_f64(), Some(lmax));

    let sol = c
        .request(&obj(vec![
            ("cmd", Json::Str("solve".into())),
            ("lambda", Json::Num(0.5 * lmax)),
        ]))
        .unwrap();
    assert_eq!(sol.get("ok"), Some(&Json::Bool(true)), "{sol:?}");

    let mut prev_rejection = 1.0;
    for frac in [0.95, 0.7, 0.4] {
        let rep = c
            .request(&obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(frac * 0.5 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let rej = rep.get("rejection").unwrap().as_f64().unwrap();
        assert!(rej <= prev_rejection + 1e-9, "rejection should shrink with gap");
        prev_rejection = rej;
    }
    server.shutdown();
}

#[test]
fn many_concurrent_clients_under_small_batches() {
    let p = Problem::from_dataset(&SynthSpec::text(60, 300, 503).generate());
    let lmax = p.lambda_max();
    let server = ScreeningServer::start(
        p,
        ServerConfig {
            workers: 8,
            batch: BatchPolicy { max_batch: 4, window: Duration::from_millis(10) },
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.addr;
    let handles: Vec<_> = (0..10)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for s in 0..5 {
                    let frac = 0.9 - 0.02 * (k as f64) - 0.1 * (s as f64);
                    let rep = c
                        .request(&obj(vec![
                            ("cmd", Json::Str("screen".into())),
                            ("lambda2", Json::Num(frac.max(0.05) * lmax)),
                        ]))
                        .unwrap();
                    assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let (screens, batches, _) = server.metrics();
    assert_eq!(screens, 50);
    assert!(batches <= 50, "batching should have merged some requests");
    server.shutdown();
}

#[test]
fn shutdown_with_idle_connection_does_not_hang() {
    let p = Problem::from_dataset(&SynthSpec::dense(30, 20, 505).generate());
    let server = ScreeningServer::start(p, ServerConfig::default()).unwrap();
    // Open a connection and never send anything.
    let _idle = std::net::TcpStream::connect(server.addr).unwrap();
    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown hung: {:?}",
        t0.elapsed()
    );
}

#[test]
fn protocol_robustness_over_the_wire() {
    use std::io::{BufRead, BufReader, Write};
    let p = Problem::from_dataset(&SynthSpec::dense(30, 20, 507).generate());
    let server = ScreeningServer::start(p, ServerConfig::default()).unwrap();
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    // Garbage line -> error response, connection stays usable.
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    line.clear();
    writeln!(w, "{{\"cmd\":\"ping\"}}").unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\":true"), "{line}");
    server.shutdown();
}
