//! Slice-level vector kernels.
//!
//! The 4-way unrolled [`dot4`] is the workhorse of the native screening
//! path: each feature evaluation needs dot products against `y`, `1`,
//! `θ₁` and its own squared norm, and computing all four in one pass over
//! the feature column halves memory traffic versus four separate dots.

/// Dot product of two equal-length slices.
///
/// Panics in debug builds if lengths differ.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks * 4..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Simultaneously computes `fᵀy`, `fᵀ1`, `fᵀθ` and `‖f‖²` in one pass.
///
/// Returns `(f·y, f·ones, f·theta, f·f)`. This is the per-feature
/// "statistics panel" the screening bound consumes (DESIGN.md §2) — the
/// native analogue of the Pallas panel matmul.
#[inline]
pub fn dot4(f: &[f64], y: &[f64], theta: &[f64]) -> (f64, f64, f64, f64) {
    debug_assert_eq!(f.len(), y.len());
    debug_assert_eq!(f.len(), theta.len());
    let (mut dy, mut d1, mut dt, mut qq) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..f.len() {
        let fi = f[i];
        dy += fi * y[i];
        d1 += fi;
        dt += fi * theta[i];
        qq += fi * fi;
    }
    (dy, d1, dt, qq)
}

/// Euclidean norm.
#[inline]
pub fn nrm2(a: &[f64]) -> f64 {
    nrm2_sq(a).sqrt()
}

/// Squared euclidean norm.
#[inline]
pub fn nrm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Sum of entries.
#[inline]
pub fn sum(a: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in a {
        acc += x;
    }
    acc
}

/// `y += alpha * x` in place.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Scales a vector in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Returns `a + alpha * b` as a new vector.
#[inline]
pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + alpha * y).collect()
}

/// Returns `a - b` as a new vector.
#[inline]
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        // length chosen to exercise the unroll remainder (4k+3)
        let a: Vec<f64> = (0..19).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..19).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn dot4_matches_separate_dots() {
        let n = 37;
        let f: Vec<f64> = (0..n).map(|i| (i as f64 * 0.77).cos()).collect();
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let th: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin().abs()).collect();
        let ones = vec![1.0; n];
        let (dy, d1, dt, qq) = dot4(&f, &y, &th);
        assert!((dy - dot(&f, &y)).abs() < 1e-12);
        assert!((d1 - dot(&f, &ones)).abs() < 1e-12);
        assert!((dt - dot(&f, &th)).abs() < 1e-12);
        assert!((qq - dot(&f, &f)).abs() < 1e-12);
    }

    #[test]
    fn axpy_and_scale() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [6.0, 7.0, 8.0]);
    }

    #[test]
    fn add_sub_helpers() {
        let a = [1.0, 2.0];
        let b = [3.0, 5.0];
        assert_eq!(add_scaled(&a, 2.0, &b), vec![7.0, 12.0]);
        assert_eq!(sub(&b, &a), vec![2.0, 3.0]);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(nrm2(&[]), 0.0);
        assert_eq!(sum(&[]), 0.0);
    }
}
