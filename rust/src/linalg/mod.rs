//! Dense linear-algebra primitives used throughout the crate.
//!
//! Everything here operates on `&[f64]` slices; there is deliberately no
//! heavyweight tensor type — the hot paths (screening bound, coordinate
//! descent) want raw slices and manual unrolling. The projection operators
//! implement Eq. (39) of the paper:
//!
//! ```text
//! P_u(v) = v - (vᵀu / ‖u‖²) u
//! ```
//!
//! which appears (singly and doubly nested) in all three closed-form cases
//! of the screening bound.

pub mod project;
pub mod vector;

pub use project::{proj_null, proj_null_dot, proj_null_norm_sq, ProjCache};
pub use vector::{
    add_scaled, axpy, dot, dot4, nrm2, nrm2_sq, scale, sub, sum,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reexports_work() {
        let v = [3.0, 4.0];
        assert!((nrm2(&v) - 5.0).abs() < 1e-12);
        assert!((dot(&v, &v) - 25.0).abs() < 1e-12);
    }
}
