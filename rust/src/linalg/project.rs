//! Null-space projection operators — Eq. (39) of the paper.
//!
//! `P_u(v) = v − (vᵀu/‖u‖²) u` projects `v` onto the orthogonal
//! complement of `u`. The screening bound needs `‖P_y(f̂)‖`,
//! `P_y(b)ᵀP_y(f̂)` and (in the β>0, α>0 case) the doubly-nested
//! `P_{P_a(y)}(P_a(·))` terms. Materializing the projected vectors is
//! O(n) *memory traffic* per feature, so the hot path instead uses the
//! scalar identities
//!
//! ```text
//! ‖P_u(v)‖²      = ‖v‖² − (vᵀu)²/‖u‖²
//! P_u(v)ᵀP_u(w)  = vᵀw − (vᵀu)(wᵀu)/‖u‖²
//! ```
//!
//! provided here as [`proj_null_norm_sq`] / [`proj_null_dot`], and a
//! [`ProjCache`] that precomputes `‖u‖²` once per shared vector.

use super::vector::{dot, nrm2_sq};

/// Materializes `P_u(v)` as a new vector. O(n); used in tests and in the
/// one-time shared precompute, never in the per-feature loop.
pub fn proj_null(u: &[f64], v: &[f64]) -> Vec<f64> {
    let uu = nrm2_sq(u);
    if uu == 0.0 {
        // Projecting onto the complement of the zero vector is the identity.
        return v.to_vec();
    }
    let c = dot(v, u) / uu;
    v.iter().zip(u).map(|(vi, ui)| vi - c * ui).collect()
}

/// `‖P_u(v)‖²` without materializing the projection.
///
/// Clamped at zero: the analytic value `‖v‖² − (vᵀu)²/‖u‖²` can go
/// slightly negative in floating point when `v` is (nearly) parallel
/// to `u`.
#[inline]
pub fn proj_null_norm_sq(v_sq: f64, v_dot_u: f64, u_sq: f64) -> f64 {
    if u_sq == 0.0 {
        return v_sq;
    }
    (v_sq - v_dot_u * v_dot_u / u_sq).max(0.0)
}

/// `P_u(v)ᵀ P_u(w)` from precomputed dots, without materializing.
#[inline]
pub fn proj_null_dot(v_dot_w: f64, v_dot_u: f64, w_dot_u: f64, u_sq: f64) -> f64 {
    if u_sq == 0.0 {
        return v_dot_w;
    }
    v_dot_w - v_dot_u * w_dot_u / u_sq
}

/// Cached `‖u‖²` plus the vector itself, for repeated projections against
/// a fixed `u` (e.g. `u = y` shared across all features).
#[derive(Debug, Clone)]
pub struct ProjCache {
    /// The projection axis.
    pub u: Vec<f64>,
    /// `‖u‖²`, precomputed.
    pub u_sq: f64,
}

impl ProjCache {
    /// Builds a cache for axis `u`.
    pub fn new(u: Vec<f64>) -> Self {
        let u_sq = nrm2_sq(&u);
        ProjCache { u, u_sq }
    }

    /// `P_u(v)` materialized.
    pub fn apply(&self, v: &[f64]) -> Vec<f64> {
        if self.u_sq == 0.0 {
            return v.to_vec();
        }
        let c = dot(v, &self.u) / self.u_sq;
        v.iter().zip(&self.u).map(|(vi, ui)| vi - c * ui).collect()
    }

    /// `‖P_u(v)‖²` given `v` (computes the two dots).
    pub fn norm_sq(&self, v: &[f64]) -> f64 {
        proj_null_norm_sq(nrm2_sq(v), dot(v, &self.u), self.u_sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vector::nrm2;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())), "{a} != {b}");
    }

    #[test]
    fn projection_is_orthogonal_to_axis() {
        let u = vec![1.0, 2.0, -1.0, 0.5];
        let v = vec![3.0, -1.0, 4.0, 2.0];
        let p = proj_null(&u, &v);
        assert_close(dot(&p, &u), 0.0, 1e-12);
    }

    #[test]
    fn projection_is_idempotent() {
        let u = vec![0.3, -2.0, 1.1];
        let v = vec![1.0, 1.0, 1.0];
        let p1 = proj_null(&u, &v);
        let p2 = proj_null(&u, &p1);
        for (a, b) in p1.iter().zip(&p2) {
            assert_close(*a, *b, 1e-12);
        }
    }

    #[test]
    fn scalar_identities_match_materialized() {
        let u = vec![1.0, -1.0, 2.0, 0.0, 3.0];
        let v = vec![2.0, 0.5, -1.0, 4.0, 1.0];
        let w = vec![-1.0, 2.0, 2.0, 1.0, 0.5];
        let pu_v = proj_null(&u, &v);
        let pu_w = proj_null(&u, &w);
        let u_sq = nrm2_sq(&u);
        assert_close(
            proj_null_norm_sq(nrm2_sq(&v), dot(&v, &u), u_sq),
            nrm2(&pu_v).powi(2),
            1e-12,
        );
        assert_close(
            proj_null_dot(dot(&v, &w), dot(&v, &u), dot(&w, &u), u_sq),
            dot(&pu_v, &pu_w),
            1e-12,
        );
    }

    #[test]
    fn zero_axis_is_identity() {
        let u = vec![0.0, 0.0];
        let v = vec![1.0, 2.0];
        assert_eq!(proj_null(&u, &v), v);
        assert_eq!(proj_null_norm_sq(5.0, 0.0, 0.0), 5.0);
    }

    #[test]
    fn parallel_vector_projects_to_zero() {
        let u = vec![1.0, 2.0, 3.0];
        let v = vec![2.0, 4.0, 6.0];
        let p = proj_null(&u, &v);
        assert_close(nrm2(&p), 0.0, 1e-12);
        // clamped identity must not go negative
        let ns = proj_null_norm_sq(nrm2_sq(&v), dot(&v, &u), nrm2_sq(&u));
        assert!(ns >= 0.0 && ns < 1e-10);
    }

    #[test]
    fn cache_matches_free_functions() {
        let cache = ProjCache::new(vec![1.0, -2.0, 0.5]);
        let v = vec![3.0, 1.0, -1.0];
        let direct = proj_null(&cache.u, &v);
        assert_eq!(cache.apply(&v), direct);
        assert_close(cache.norm_sq(&v), nrm2_sq(&direct), 1e-12);
    }
}
