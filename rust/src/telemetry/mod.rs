//! In-tree telemetry: metrics registry, structured spans and event
//! sinks.
//!
//! The paper's whole argument is *measured* — rejection ratios,
//! screen-vs-solve time, safety violations. This module is the single
//! surface every hot layer reports into, std-only because the vendored
//! crate set has no `tracing`/`log`/`prometheus`:
//!
//! * [`metrics`] — a global, lock-cheap registry of named counters,
//!   gauges and log-scale histograms ([`global()`]); snapshots carry
//!   p50/p90/p99 and render to protocol JSON or Prometheus text
//!   ([`crate::report::prometheus`]).
//! * [`span`] — RAII [`Span`] guards recording nested wall-time, used
//!   by the path runner and the server instead of raw stopwatches.
//! * [`sink`] — a leveled stderr logger (`PALLAS_LOG=debug`) plus an
//!   optional JSONL trace file (`PALLAS_LOG_JSON=path`), with the
//!   [`tele_error!`](crate::tele_error)…[`tele_trace!`](crate::tele_trace)
//!   macros as the front end.
//! * [`trace`] — a fixed-capacity ring of completed span / instant
//!   records (`PALLAS_TRACE_CAPACITY`, default 16384) exportable as
//!   Chrome trace-event JSON (Perfetto / `chrome://tracing`) via
//!   `--trace-out`, `PALLAS_TRACE_OUT` or the `{"cmd":"trace"}`
//!   protocol command.
//! * [`dump`] — a periodic stats-dump thread for long `serve` runs
//!   (`PALLAS_STATS_DUMP_SECS`), pushing full snapshots through the
//!   sinks.
//!
//! ## Instrumented layers
//!
//! | layer | metrics (prefix) | events |
//! |---|---|---|
//! | solver CD / FISTA | `solver.cd.*`, `solver.fista.*` | solve summary (debug), gap checks (trace) |
//! | screening sweeps | `screening.*` incl. per-rule rejection/kept-set | per-sweep summary (debug) |
//! | safety audit | `screening.violations`, `screening.audit.*` | error event per KKT violation |
//! | path runner | `path.*` + spans `path.run/screen/solve` | per-step `PathStep` events (debug) |
//! | coordinator | `server.*` request/latency/batch bytes | connection + request events |
//! | diagnostics | `screening.margin.*`, `screening.*.near_miss`, `solver.anomalies`, `diag.ledger.*`, `telemetry.trace.dropped` | `solver.anomaly` warn instants |
//!
//! The server exposes all of it live via the `{"cmd":"stats"}`,
//! `{"cmd":"trace"}` and `{"cmd":"diag"}` protocol commands. Per-entity
//! diagnostics (the provenance ledger and convergence log feeding the
//! `diag.*` metrics) live in [`crate::diag`].
//!
//! ## Quick use
//!
//! ```
//! use svmscreen::telemetry::{self, Span};
//!
//! telemetry::init_from_env(); // reads PALLAS_LOG / PALLAS_LOG_JSON
//! telemetry::global().counter("demo.events").inc();
//! let span = Span::enter("demo.work");
//! svmscreen::tele_debug!("demo", "inside {}", telemetry::current_path());
//! drop(span); // records demo.work.seconds
//! assert!(telemetry::global().snapshot().counters["demo.events"] >= 1);
//! ```

pub mod dump;
pub mod metrics;
pub mod sink;
pub mod span;
pub mod trace;

pub use dump::{start_stats_dump, start_stats_dump_from_env};
pub use metrics::{
    global, BucketSpec, Counter, Gauge, Histogram, HistogramSnapshot,
    MetricsSnapshot, Registry,
};
pub use sink::{emit, emit_with, enabled, init_from_env, set_stderr_level, Level};
pub use span::{adopt_path, current_path, depth, Span};
pub use trace::{TraceRecord, TraceRing};

/// Emits an event at an explicit [`Level`]; the message formats lazily
/// (only when some sink would accept the event).
#[macro_export]
macro_rules! tele_log {
    ($level:expr, $target:expr, $($arg:tt)+) => {
        if $crate::telemetry::enabled($level) {
            $crate::telemetry::emit($level, $target, &format!($($arg)+));
        }
    };
}

/// Emits an error-level event.
#[macro_export]
macro_rules! tele_error {
    ($target:expr, $($arg:tt)+) => {
        $crate::tele_log!($crate::telemetry::Level::Error, $target, $($arg)+)
    };
}

/// Emits a warn-level event.
#[macro_export]
macro_rules! tele_warn {
    ($target:expr, $($arg:tt)+) => {
        $crate::tele_log!($crate::telemetry::Level::Warn, $target, $($arg)+)
    };
}

/// Emits an info-level event.
#[macro_export]
macro_rules! tele_info {
    ($target:expr, $($arg:tt)+) => {
        $crate::tele_log!($crate::telemetry::Level::Info, $target, $($arg)+)
    };
}

/// Emits a debug-level event.
#[macro_export]
macro_rules! tele_debug {
    ($target:expr, $($arg:tt)+) => {
        $crate::tele_log!($crate::telemetry::Level::Debug, $target, $($arg)+)
    };
}

/// Emits a trace-level event.
#[macro_export]
macro_rules! tele_trace {
    ($target:expr, $($arg:tt)+) => {
        $crate::tele_log!($crate::telemetry::Level::Trace, $target, $($arg)+)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macros_compile_and_respect_levels() {
        init_from_env();
        crate::tele_error!("telemetry.test", "count = {}", 1);
        crate::tele_warn!("telemetry.test", "count = {}", 2);
        crate::tele_info!("telemetry.test", "plain");
        crate::tele_debug!("telemetry.test", "x={x}", x = 3);
        crate::tele_trace!("telemetry.test", "deep");
    }

    #[test]
    fn global_registry_is_shared() {
        global().counter("telemetry.mod.test").add(2);
        assert!(global().snapshot().counters["telemetry.mod.test"] >= 2);
    }
}
