//! Trace recorder: a fixed-capacity ring buffer of completed spans and
//! instant events, with zero-dependency exporters to Chrome trace-event
//! JSON (loadable in Perfetto / `chrome://tracing`) and JSONL.
//!
//! Metrics ([`super::metrics`]) answer *how much / how fast on average*;
//! the trace answers *what happened when*. Every [`super::span::Span`]
//! records itself here on close (name, label, start, duration, thread,
//! nesting depth), and warn/error events from the sinks land as instant
//! markers, so a drained ring replays the run's timeline — per-λ screen
//! and solve phases, batched server sweeps, safety-audit violations.
//!
//! Surfaces:
//!
//! * `{"cmd":"trace"}` — the coordinator protocol command drains the
//!   ring over the wire ([`crate::coordinator::server`]).
//! * `--trace-out FILE` — the CLI writes the ring as a Chrome trace
//!   after `solve` / `screen` / `path`.
//! * `PALLAS_TRACE_OUT=FILE` — benches write the same file via
//!   [`crate::report::bench::BenchArtifact`].
//! * `PALLAS_TRACE_CAPACITY=N` — ring capacity (default 16384; `0`
//!   disables recording entirely).
//!
//! The ring is bounded: when full, the oldest record is evicted — never
//! silently. Evictions are counted twice: per-drain ([`TraceRing::dropped`],
//! reset by [`TraceRing::drain`] and reported by `{"cmd":"trace"}`) and
//! cumulatively ([`TraceRing::dropped_total`], mirrored live into the
//! `telemetry.trace.dropped` gauge), so long `serve` runs never grow
//! without bound and lost records are always visible in stats snapshots.

use crate::coordinator::protocol::Json;
use crate::telemetry::metrics::Gauge;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default ring capacity when `PALLAS_TRACE_CAPACITY` is unset.
pub const DEFAULT_CAPACITY: usize = 16_384;

/// What a [`TraceRecord`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    /// A completed span (has a duration).
    Span,
    /// A point-in-time marker (warn/error events, audit violations).
    Instant,
}

impl RecordKind {
    /// Chrome trace-event phase letter: `X` (complete) or `i` (instant).
    pub fn phase(&self) -> &'static str {
        match self {
            RecordKind::Span => "X",
            RecordKind::Instant => "i",
        }
    }
}

/// One completed span or instant event, as captured by the ring.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// Span/event name (dotted-metric style, e.g. `path.solve`).
    pub name: String,
    /// Free-form label (e.g. the λ being solved), if any.
    pub label: Option<String>,
    /// Record kind (span vs instant marker).
    pub kind: RecordKind,
    /// Microseconds since the process trace epoch at which it started.
    pub ts_us: u64,
    /// Duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// Small dense per-process thread id (not the OS tid).
    pub tid: u64,
    /// Span-stack nesting depth at which the record was produced.
    pub depth: usize,
}

impl TraceRecord {
    /// The record as a flat JSON object (JSONL export, protocol drain).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("ph", Json::Str(self.kind.phase().into())),
            ("ts_us", Json::Num(self.ts_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
            ("tid", Json::Num(self.tid as f64)),
            ("depth", Json::Num(self.depth as f64)),
        ];
        if let Some(l) = &self.label {
            fields.push(("label", Json::Str(l.clone())));
        }
        Json::obj(fields)
    }

    /// The record as a Chrome trace-event object (`ph: "X"` complete
    /// events for spans, `ph: "i"` thread-scoped instants).
    pub fn to_chrome_event(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("cat", Json::Str(category(&self.name).into())),
            ("ph", Json::Str(self.kind.phase().into())),
            ("ts", Json::Num(self.ts_us as f64)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(self.tid as f64)),
        ];
        match self.kind {
            RecordKind::Span => fields.push(("dur", Json::Num(self.dur_us as f64))),
            // Thread-scoped instant marker.
            RecordKind::Instant => fields.push(("s", Json::Str("t".into()))),
        }
        let mut args = vec![("depth", Json::Num(self.depth as f64))];
        if let Some(l) = &self.label {
            args.push(("label", Json::Str(l.clone())));
        }
        fields.push(("args", Json::obj(args)));
        Json::obj(fields)
    }
}

/// The first dotted segment of a name (`path.solve` → `path`), used as
/// the Chrome trace category so Perfetto can filter by subsystem.
fn category(name: &str) -> &str {
    name.split('.').next().unwrap_or("misc")
}

struct RingInner {
    buf: VecDeque<TraceRecord>,
    dropped: u64,
    dropped_total: u64,
}

/// A bounded, thread-safe recorder of [`TraceRecord`]s. The global
/// instance lives behind [`recorder`]; tests may build private ones.
pub struct TraceRing {
    capacity: usize,
    inner: Mutex<RingInner>,
    dropped_gauge: OnceLock<Arc<Gauge>>,
}

impl TraceRing {
    /// Creates a ring holding at most `capacity` records (0 = disabled:
    /// every record is silently discarded).
    pub fn new(capacity: usize) -> Self {
        TraceRing {
            capacity,
            inner: Mutex::new(RingInner {
                buf: VecDeque::with_capacity(capacity.min(1024)),
                dropped: 0,
                dropped_total: 0,
            }),
            dropped_gauge: OnceLock::new(),
        }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Mirrors the cumulative eviction count into `gauge` on every
    /// future eviction (the global ring attaches the registry's
    /// `telemetry.trace.dropped`). First attachment wins.
    pub fn attach_dropped_gauge(&self, gauge: Arc<Gauge>) {
        gauge.set(self.inner.lock().unwrap().dropped_total as f64);
        let _ = self.dropped_gauge.set(gauge);
    }

    /// Records one trace record, evicting the oldest when full.
    pub fn record(&self, rec: TraceRecord) {
        if self.capacity == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.buf.len() >= self.capacity {
            inner.buf.pop_front();
            inner.dropped += 1;
            inner.dropped_total += 1;
            if let Some(g) = self.dropped_gauge.get() {
                g.set(inner.dropped_total as f64);
            }
        }
        inner.buf.push_back(rec);
    }

    /// Current number of buffered records.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    /// Whether the ring holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records evicted (ring-full overwrites) since the last [`drain`].
    ///
    /// [`drain`]: TraceRing::drain
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Records evicted since the ring was created — never reset, and
    /// mirrored into the attached gauge ([`TraceRing::attach_dropped_gauge`]).
    pub fn dropped_total(&self) -> u64 {
        self.inner.lock().unwrap().dropped_total
    }

    /// Removes and returns every buffered record (oldest first) and
    /// resets the dropped counter.
    pub fn drain(&self) -> Vec<TraceRecord> {
        let mut inner = self.inner.lock().unwrap();
        inner.dropped = 0;
        inner.buf.drain(..).collect()
    }

    /// Clones the buffered records without consuming them.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        self.inner.lock().unwrap().buf.iter().cloned().collect()
    }
}

/// The process-wide trace ring. Capacity comes from
/// `PALLAS_TRACE_CAPACITY` at first use (default [`DEFAULT_CAPACITY`]).
pub fn recorder() -> &'static TraceRing {
    static GLOBAL: OnceLock<TraceRing> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let capacity = std::env::var("PALLAS_TRACE_CAPACITY")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAPACITY);
        let ring = TraceRing::new(capacity);
        // Register the eviction gauge up front so it shows as 0 in
        // stats snapshots before the first wrap.
        ring.attach_dropped_gauge(
            crate::telemetry::global().gauge("telemetry.trace.dropped"),
        );
        ring
    })
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (first telemetry use).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// A small dense id for the calling thread (assigned on first use, in
/// order of first trace activity — Chrome traces want integer tids).
pub fn thread_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// Records a completed span into the global ring (called by
/// [`super::span::Span`] on close).
pub fn record_span(
    name: &str,
    label: Option<&str>,
    start_us: u64,
    dur_us: u64,
    depth: usize,
) {
    recorder().record(TraceRecord {
        name: name.to_string(),
        label: label.map(str::to_string),
        kind: RecordKind::Span,
        ts_us: start_us,
        dur_us,
        tid: thread_id(),
        depth,
    });
}

/// Records an instant marker into the global ring.
pub fn instant(name: &str, label: Option<&str>) {
    recorder().record(TraceRecord {
        name: name.to_string(),
        label: label.map(str::to_string),
        kind: RecordKind::Instant,
        ts_us: now_us(),
        dur_us: 0,
        tid: thread_id(),
        depth: super::span::depth(),
    });
}

/// Renders records as a Chrome trace-event document:
/// `{"traceEvents":[...],"displayTimeUnit":"ms"}`. Perfetto and
/// `chrome://tracing` load the encoded string directly.
pub fn chrome_trace(records: &[TraceRecord]) -> Json {
    Json::obj(vec![
        (
            "traceEvents",
            Json::Arr(records.iter().map(TraceRecord::to_chrome_event).collect()),
        ),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Renders records as JSONL — one flat JSON object per line.
pub fn to_jsonl(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json().encode());
        out.push('\n');
    }
    out
}

/// Drains the global ring and writes it as a Chrome trace file.
/// Returns the number of records written.
pub fn write_chrome_file(path: &str) -> std::io::Result<usize> {
    let records = recorder().drain();
    std::fs::write(path, chrome_trace(&records).encode())?;
    Ok(records.len())
}

/// Writes the Chrome trace to `$PALLAS_TRACE_OUT` when set (bench and
/// scripting hook). Returns the records written, or `None` when the
/// variable is unset or the write fails (failure is reported on stderr,
/// never fatal).
pub fn write_from_env() -> Option<usize> {
    let path = std::env::var("PALLAS_TRACE_OUT").ok()?;
    match write_chrome_file(&path) {
        Ok(n) => {
            println!("[trace] wrote {path} ({n} records)");
            Some(n)
        }
        Err(e) => {
            eprintln!("trace: cannot write {path}: {e}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse;

    fn rec(name: &str, kind: RecordKind, ts: u64) -> TraceRecord {
        TraceRecord {
            name: name.into(),
            label: Some("k=1".into()),
            kind,
            ts_us: ts,
            dur_us: if kind == RecordKind::Span { 5 } else { 0 },
            tid: 1,
            depth: 0,
        }
    }

    #[test]
    fn ring_records_and_drains_in_order() {
        let ring = TraceRing::new(8);
        for i in 0..5 {
            ring.record(rec("a.b", RecordKind::Span, i));
        }
        assert_eq!(ring.len(), 5);
        let drained = ring.drain();
        assert_eq!(drained.len(), 5);
        assert!(drained.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        assert!(ring.is_empty());
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let ring = TraceRing::new(4);
        for i in 0..10 {
            ring.record(rec("a", RecordKind::Span, i));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped(), 6);
        // Oldest evicted: the survivors are the last four.
        let recs = ring.snapshot();
        assert_eq!(recs.first().unwrap().ts_us, 6);
        assert_eq!(recs.last().unwrap().ts_us, 9);
        // Drain resets the per-drain counter, not the cumulative one.
        ring.drain();
        assert_eq!(ring.dropped(), 0);
        assert_eq!(ring.dropped_total(), 6);
    }

    #[test]
    fn dropped_gauge_tracks_cumulative_evictions() {
        let r = crate::telemetry::Registry::new();
        let ring = TraceRing::new(2);
        ring.attach_dropped_gauge(r.gauge("telemetry.trace.dropped"));
        assert_eq!(r.gauge("telemetry.trace.dropped").get(), 0.0);
        for i in 0..5 {
            ring.record(rec("a", RecordKind::Span, i));
        }
        assert_eq!(ring.dropped(), 3);
        assert_eq!(ring.dropped_total(), 3);
        assert_eq!(r.gauge("telemetry.trace.dropped").get(), 3.0);
        ring.drain();
        assert_eq!(ring.dropped(), 0);
        // The gauge survives the drain: it mirrors the total.
        assert_eq!(r.gauge("telemetry.trace.dropped").get(), 3.0);
        for i in 0..5 {
            ring.record(rec("b", RecordKind::Span, i));
        }
        assert_eq!(ring.dropped_total(), 6);
        assert_eq!(r.gauge("telemetry.trace.dropped").get(), 6.0);
    }

    #[test]
    fn zero_capacity_disables() {
        let ring = TraceRing::new(0);
        ring.record(rec("a", RecordKind::Span, 1));
        assert!(ring.is_empty());
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn chrome_export_is_valid_json_with_required_keys() {
        let records = vec![
            rec("path.screen", RecordKind::Span, 10),
            rec("screening.violation", RecordKind::Instant, 12),
        ];
        let doc = chrome_trace(&records);
        let parsed = parse(&doc.encode()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let span = &events[0];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("cat").unwrap().as_str(), Some("path"));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(10.0));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(5.0));
        assert!(span.get("pid").is_some() && span.get("tid").is_some());
        let inst = &events[1];
        assert_eq!(inst.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(inst.get("s").unwrap().as_str(), Some("t"));
        assert!(inst.get("dur").is_none());
    }

    #[test]
    fn jsonl_one_object_per_line() {
        let records =
            vec![rec("a", RecordKind::Span, 1), rec("b", RecordKind::Instant, 2)];
        let text = to_jsonl(&records);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = parse(line).unwrap();
            assert!(v.get("name").is_some());
            assert!(v.get("ts_us").is_some());
        }
    }

    #[test]
    fn thread_ids_are_distinct_and_stable() {
        let here = thread_id();
        assert_eq!(here, thread_id());
        let other = std::thread::spawn(thread_id).join().unwrap();
        assert_ne!(here, other);
    }

    #[test]
    fn now_us_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
