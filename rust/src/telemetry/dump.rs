//! Periodic stats dumps for long-running processes.
//!
//! `serve` runs for hours; scraping `{"cmd":"stats"}` needs a client.
//! This module adds a push path: a background thread that emits the
//! full metrics snapshot every `PALLAS_STATS_DUMP_SECS` seconds as an
//! info-level `stats.dump` event — a one-line summary on stderr (at
//! `PALLAS_LOG=info`) and the complete snapshot JSON through the JSONL
//! sink (`PALLAS_LOG_JSON`), so a long service run leaves a sampled
//! time series of every counter and latency percentile behind.

use super::metrics;
use super::sink::{self, Level};
use std::time::Duration;

/// Emits one `stats.dump` event with the current global snapshot.
pub fn dump_once() {
    let snap = metrics::global().snapshot();
    let summary = format!(
        "{} counters, {} gauges, {} histograms",
        snap.counters.len(),
        snap.gauges.len(),
        snap.histograms.len()
    );
    sink::emit_with(Level::Info, "stats.dump", &summary, Some(&snap.to_json()));
}

/// Spawns a detached thread dumping stats every `every`. The thread
/// runs for the life of the process (it is only started by long-lived
/// entry points such as `serve`).
pub fn start_stats_dump(every: Duration) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("stats-dump".into())
        .spawn(move || loop {
            std::thread::sleep(every);
            dump_once();
        })
        .expect("spawn stats-dump thread")
}

/// Reads `PALLAS_STATS_DUMP_SECS` and starts the dump thread when it
/// parses to a positive number of seconds. Returns the interval that
/// was armed, if any.
pub fn start_stats_dump_from_env() -> Option<Duration> {
    let secs = std::env::var("PALLAS_STATS_DUMP_SECS")
        .ok()?
        .trim()
        .parse::<f64>()
        .ok()
        .filter(|s| *s > 0.0 && s.is_finite())?;
    let every = Duration::from_secs_f64(secs);
    start_stats_dump(every);
    Some(every)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dump_once_does_not_panic_and_counts_metrics() {
        metrics::global().counter("dump.test.events").inc();
        // Emits through the sinks; must never panic regardless of level.
        dump_once();
    }

    #[test]
    fn env_unset_or_invalid_is_none() {
        // The test environment does not define the variable; an absent
        // or unparsable value must not spawn a thread.
        if std::env::var("PALLAS_STATS_DUMP_SECS").is_err() {
            assert!(start_stats_dump_from_env().is_none());
        }
    }
}
