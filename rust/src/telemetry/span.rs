//! Structured spans: RAII guards that record nested wall-time.
//!
//! A [`Span`] measures the wall-clock time between [`Span::enter`] and
//! drop, records it into the global histogram `<name>.seconds`, and
//! emits paired `begin`/`end` debug events so `PALLAS_LOG=debug` shows
//! an indented trace of the nesting. The per-thread span stack gives
//! every event its enclosing span path (`path.run/path.solve`), which
//! the JSONL sink records verbatim.
//!
//! Spans replace the raw `Instant`/`Stopwatch` timing that used to be
//! scattered through `path/runner.rs` and `coordinator/server.rs`:
//! the same reading is now *also* a named metric, for free — and every
//! closed span additionally lands in the [trace ring](super::trace) so
//! exported timelines (Perfetto, `{"cmd":"trace"}`) replay the nesting.

use super::metrics;
use super::sink::{self, Level};
use super::trace;
use std::cell::RefCell;
use std::time::Instant;

thread_local! {
    static STACK: RefCell<Vec<String>> = const { RefCell::new(Vec::new()) };
}

/// Current nesting depth on this thread.
pub fn depth() -> usize {
    STACK.with(|s| s.borrow().len())
}

/// The enclosing span path on this thread, `/`-joined (empty at top
/// level).
pub fn current_path() -> String {
    STACK.with(|s| s.borrow().join("/"))
}

/// RAII guard installed by [`adopt_path`]: pops the adopted frames on
/// drop.
#[derive(Debug)]
pub struct AdoptedPath {
    frames: usize,
}

impl Drop for AdoptedPath {
    fn drop(&mut self) {
        STACK.with(|s| {
            let mut stack = s.borrow_mut();
            let keep = stack.len().saturating_sub(self.frames);
            stack.truncate(keep);
        });
    }
}

/// Adopts a parent span path (as returned by [`current_path`]) on this
/// thread: spans opened while the guard lives nest *under* the parent,
/// so work shipped to pool workers keeps its attribution instead of
/// collapsing to depth 0 in exported traces. The guard pops the
/// adopted frames on drop.
pub fn adopt_path(parent: &str) -> AdoptedPath {
    let frames: Vec<String> =
        parent.split('/').filter(|s| !s.is_empty()).map(str::to_string).collect();
    let n = frames.len();
    STACK.with(|s| s.borrow_mut().extend(frames));
    AdoptedPath { frames: n }
}

/// An RAII wall-time span. Construct with [`Span::enter`]; the drop
/// records `<name>.seconds` into the [global registry](metrics::global).
#[derive(Debug)]
pub struct Span {
    name: String,
    label: Option<String>,
    start: Instant,
    start_us: u64,
    depth: usize,
    armed: bool,
}

impl Span {
    /// Opens a span named `name` (dotted-metric style, e.g.
    /// `"path.solve"`).
    pub fn enter(name: impl Into<String>) -> Span {
        Span::enter_labeled(name, None::<String>)
    }

    /// Opens a span with a free-form label carried on its events (e.g.
    /// the λ being solved). Labels do not affect the metric name.
    pub fn enter_labeled(
        name: impl Into<String>,
        label: Option<impl Into<String>>,
    ) -> Span {
        let name = name.into();
        let label = label.map(Into::into);
        if sink::enabled(Level::Debug) {
            match &label {
                Some(l) => sink::emit(Level::Debug, &name, &format!("begin ({l})")),
                None => sink::emit(Level::Debug, &name, "begin"),
            }
        }
        let depth = depth();
        STACK.with(|s| s.borrow_mut().push(name.clone()));
        Span {
            name,
            label,
            start: Instant::now(),
            start_us: trace::now_us(),
            depth,
            armed: true,
        }
    }

    /// Seconds elapsed so far (the span keeps running).
    pub fn elapsed_seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Closes the span now and returns the elapsed seconds — for call
    /// sites that also need the reading (e.g. `PathStep` fields).
    pub fn finish(mut self) -> f64 {
        self.close()
    }

    fn close(&mut self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if self.armed {
            self.armed = false;
            STACK.with(|s| {
                let mut stack = s.borrow_mut();
                // Pop our own frame. Spans are almost always dropped in
                // LIFO order; if a caller held one across scopes, remove
                // the deepest matching frame instead of corrupting the
                // stack.
                if let Some(i) = stack.iter().rposition(|n| n == &self.name) {
                    stack.remove(i);
                }
            });
            metrics::global()
                .histogram(&format!("{}.seconds", self.name))
                .record(secs);
            trace::record_span(
                &self.name,
                self.label.as_deref(),
                self.start_us,
                self.start.elapsed().as_micros() as u64,
                self.depth,
            );
            if sink::enabled(Level::Debug) {
                let lbl = self
                    .label
                    .as_deref()
                    .map(|l| format!(" ({l})"))
                    .unwrap_or_default();
                sink::emit(
                    Level::Debug,
                    &self.name,
                    &format!("end{lbl} {}", crate::report::timer::fmt_duration(secs)),
                );
            }
        }
        secs
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_into_global_histogram() {
        let before = metrics::global().histogram("test.span.seconds").count();
        {
            let s = Span::enter("test.span");
            assert!(s.elapsed_seconds() >= 0.0);
        }
        let after = metrics::global().histogram("test.span.seconds").count();
        assert_eq!(after, before + 1);
    }

    #[test]
    fn nesting_tracks_depth_and_path() {
        assert_eq!(depth(), 0);
        let outer = Span::enter("test.outer");
        assert_eq!(depth(), 1);
        {
            let _inner = Span::enter_labeled("test.inner", Some("k=1"));
            assert_eq!(depth(), 2);
            assert_eq!(current_path(), "test.outer/test.inner");
        }
        assert_eq!(depth(), 1);
        assert_eq!(current_path(), "test.outer");
        let secs = outer.finish();
        assert!(secs >= 0.0);
        assert_eq!(depth(), 0);
    }

    #[test]
    fn adopt_path_nests_and_restores() {
        assert_eq!(current_path(), "");
        {
            let _g = adopt_path("path.run/path.solve");
            assert_eq!(depth(), 2);
            assert_eq!(current_path(), "path.run/path.solve");
            let inner = Span::enter("test.adopted");
            assert_eq!(depth(), 3);
            assert_eq!(current_path(), "path.run/path.solve/test.adopted");
            drop(inner);
            assert_eq!(depth(), 2);
        }
        assert_eq!(depth(), 0);
        // Empty parent adopts nothing.
        let _g = adopt_path("");
        assert_eq!(depth(), 0);
    }

    #[test]
    fn closed_span_lands_in_trace_ring() {
        // The ring is process-global and other tests may drain it
        // (`{"cmd":"trace"}` round-trips); retry so a drain landing
        // between our record and our check can't flake this test.
        let mut found = false;
        for _ in 0..50 {
            drop(Span::enter("test.traced"));
            let snap = crate::telemetry::trace::recorder().snapshot();
            if snap.iter().any(|r| {
                r.name == "test.traced" && r.kind == crate::telemetry::trace::RecordKind::Span
            }) {
                found = true;
                break;
            }
        }
        assert!(found, "span never reached the trace ring");
    }

    #[test]
    fn finish_returns_seconds_once() {
        let before = metrics::global().histogram("test.once.seconds").count();
        let s = Span::enter("test.once");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let secs = s.finish();
        assert!(secs >= 0.001, "{secs}");
        // finish consumed the span; exactly one sample recorded
        let after = metrics::global().histogram("test.once.seconds").count();
        assert_eq!(after, before + 1);
    }
}
