//! Event sinks: the leveled stderr logger and the optional JSONL trace
//! file.
//!
//! Configuration is environment-driven so the binary, the benches and
//! the tests share one switch:
//!
//! * `PALLAS_LOG=error|warn|info|debug|trace` — stderr verbosity
//!   (default `warn`; anything unparsable falls back to `warn`).
//! * `PALLAS_LOG_JSON=path.jsonl` — additionally append every emitted
//!   event as one JSON object per line (machine-readable traces).
//!
//! The vendored crate set has no `log`/`tracing`, so this is the
//! crate's logging facade; the [`crate::tele_debug!`]-family macros
//! route here. Events below the configured level cost one relaxed
//! atomic load.

use crate::coordinator::protocol::Json;
use std::fs::OpenOptions;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or data-loss conditions.
    Error = 0,
    /// Suspicious but handled conditions.
    Warn = 1,
    /// High-level lifecycle events.
    Info = 2,
    /// Per-operation detail (spans, steps, requests).
    Debug = 3,
    /// Inner-loop detail (gap checks, batch contents).
    Trace = 4,
}

impl Level {
    /// Parses a level name (case-insensitive). `off` disables stderr.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Display name (fixed 5 columns for aligned stderr output).
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// `u8::MAX` marks "stderr disabled" (PALLAS_LOG=off).
const STDERR_OFF: u8 = u8::MAX;

struct Sinks {
    stderr_level: AtomicU8,
    json: Option<Mutex<std::fs::File>>,
}

fn sinks() -> &'static Sinks {
    static SINKS: OnceLock<Sinks> = OnceLock::new();
    SINKS.get_or_init(|| {
        let stderr_level = match std::env::var("PALLAS_LOG") {
            Ok(v) if v.trim().eq_ignore_ascii_case("off") => STDERR_OFF,
            Ok(v) => Level::parse(&v).unwrap_or(Level::Warn) as u8,
            Err(_) => Level::Warn as u8,
        };
        let json = std::env::var("PALLAS_LOG_JSON").ok().and_then(|path| {
            OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .map_err(|e| eprintln!("telemetry: cannot open {path}: {e}"))
                .ok()
                .map(Mutex::new)
        });
        Sinks { stderr_level: AtomicU8::new(stderr_level), json }
    })
}

/// Reads `PALLAS_LOG`/`PALLAS_LOG_JSON` and installs the sinks. Called
/// by `main`; safe (and idempotent) to call from tests and benches —
/// first caller wins, matching `OnceLock` semantics.
pub fn init_from_env() {
    let _ = sinks();
}

/// Overrides the stderr level at runtime (CLI `--log` flag).
pub fn set_stderr_level(level: Option<Level>) {
    sinks()
        .stderr_level
        .store(level.map(|l| l as u8).unwrap_or(STDERR_OFF), Ordering::Relaxed);
}

/// Whether an event at `level` would reach any sink. Use to guard
/// expensive formatting: `if enabled(Level::Trace) { ... }`.
pub fn enabled(level: Level) -> bool {
    let s = sinks();
    let stderr_on = match s.stderr_level.load(Ordering::Relaxed) {
        STDERR_OFF => false,
        max => level <= Level::from_u8(max),
    };
    stderr_on || s.json.is_some()
}

/// Emits a plain message event.
pub fn emit(level: Level, target: &str, msg: &str) {
    emit_with(level, target, msg, None);
}

/// Emits an event with optional structured `fields` (JSONL sink only;
/// the stderr line stays human-oriented).
pub fn emit_with(level: Level, target: &str, msg: &str, fields: Option<&Json>) {
    // Warn/error events are rare and load-bearing (safety violations,
    // repair loops): mirror them into the trace ring as instant markers
    // so exported timelines show *when* they happened.
    if level <= Level::Warn {
        super::trace::instant(target, Some(msg));
    }
    let s = sinks();
    let ts = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let span = super::span::current_path();
    let stderr_max = s.stderr_level.load(Ordering::Relaxed);
    if stderr_max != STDERR_OFF && level <= Level::from_u8(stderr_max) {
        let indent = "  ".repeat(super::span::depth());
        let span_note =
            if span.is_empty() { String::new() } else { format!(" [{span}]") };
        eprintln!("[{:13.3} {}] {indent}{target}{span_note}: {msg}", ts, level.name());
    }
    if let Some(file) = &s.json {
        let mut obj = vec![
            ("ts", Json::Num(ts)),
            ("level", Json::Str(level.name().trim().to_ascii_lowercase())),
            ("target", Json::Str(target.to_string())),
            ("msg", Json::Str(msg.to_string())),
        ];
        if !span.is_empty() {
            obj.push(("span", Json::Str(span)));
        }
        if let Some(f) = fields {
            obj.push(("fields", f.clone()));
        }
        let line = Json::obj(obj).encode();
        let mut guard = file.lock().unwrap();
        let _ = writeln!(guard, "{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::from_u8(Level::Debug as u8), Level::Debug);
    }

    #[test]
    fn runtime_level_override_gates_enabled() {
        init_from_env();
        set_stderr_level(Some(Level::Error));
        // Error must always be visible on stderr.
        assert!(enabled(Level::Error));
        set_stderr_level(Some(Level::Trace));
        assert!(enabled(Level::Trace));
        // emit must not panic at any level
        emit(Level::Trace, "test", "trace event");
        emit_with(
            Level::Error,
            "test",
            "structured",
            Some(&Json::obj(vec![("k", Json::Num(1.0))])),
        );
        // restore a quiet default for the rest of the test binary
        set_stderr_level(Some(Level::Warn));
    }
}
