//! The global metrics registry: named counters, gauges and log-scale
//! histograms, all lock-free on the hot path.
//!
//! Design constraints (same as the rest of the crate): std-only, no
//! `metrics`/`prometheus` crates in the vendored set. Handles returned
//! by [`Registry::counter`]/[`gauge`](Registry::gauge)/
//! [`histogram`](Registry::histogram) are `Arc`s — look a metric up
//! once (registry lookup takes a mutex) and then update it with plain
//! relaxed atomics from any thread.
//!
//! Histograms are log-scale. The default [`BucketSpec::SECONDS`] uses
//! half-power-of-two buckets spanning `[2⁻³⁰ s, 2⁸ s]` (≈1 ns … ≈4 min),
//! which bounds the quantile estimation error at ~19% — plenty for
//! latency percentiles — while keeping `record` a single atomic
//! increment. Non-latency quantities (batch bytes, kept-set sizes) use
//! [`BucketSpec::COUNTS`] via [`Registry::histogram_with`]: power-of-two
//! buckets over `[1, 2⁴⁰]`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotone event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Adds 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Adds `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge (f64 stored as bits).
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge { bits: AtomicU64::new(0.0f64.to_bits()) }
    }
}

impl Gauge {
    /// Sets the gauge.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log-bucket layout of a [`Histogram`]: `per_pow2` buckets per power
/// of two over `[2^min_exp, 2^max_exp]`, plus one overflow bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketSpec {
    /// Smallest bucket lower edge is `2^min_exp`.
    pub min_exp: i32,
    /// Largest bucket upper edge is `2^max_exp`.
    pub max_exp: i32,
    /// Buckets per power of two (resolution).
    pub per_pow2: i32,
}

impl BucketSpec {
    /// Latency buckets: `[2⁻³⁰ s, 2⁸ s]` (≈1 ns … ≈4 min) at half-power
    /// resolution. The default for [`Registry::histogram`].
    pub const SECONDS: BucketSpec = BucketSpec { min_exp: -30, max_exp: 8, per_pow2: 2 };

    /// Count/byte buckets: `[1, 2⁴⁰]` (~10¹²) at power-of-two
    /// resolution — batch sizes, payload bytes, kept-set sizes.
    pub const COUNTS: BucketSpec = BucketSpec { min_exp: 0, max_exp: 40, per_pow2: 1 };

    /// Margin buckets: `[2⁻⁴⁰, 2¹⁰]` at power-of-two resolution —
    /// screening-bound margins `|bound − threshold|`, which span from
    /// ulp-scale near-misses to O(1) comfortable rejections
    /// (`screening.margin.*`, recorded by the diag ledger).
    pub const MARGINS: BucketSpec = BucketSpec { min_exp: -40, max_exp: 10, per_pow2: 1 };

    /// Number of buckets (plus one overflow bucket at the end).
    fn n_buckets(&self) -> usize {
        ((self.max_exp - self.min_exp) * self.per_pow2) as usize + 1
    }

    /// Maps a sample to its bucket index.
    fn bucket_index(&self, v: f64) -> usize {
        if v <= 0.0 {
            return 0;
        }
        let idx =
            ((v.log2() - self.min_exp as f64) * self.per_pow2 as f64).floor() as i64;
        idx.clamp(0, self.n_buckets() as i64 - 1) as usize
    }

    /// Geometric midpoint of bucket `i` (its quantile representative).
    fn bucket_mid(&self, i: usize) -> f64 {
        let lower_log2 = self.min_exp as f64 + i as f64 / self.per_pow2 as f64;
        (lower_log2 + 0.5 / self.per_pow2 as f64).exp2()
    }
}

/// A log-scale histogram of nonnegative f64 samples.
#[derive(Debug)]
pub struct Histogram {
    spec: BucketSpec,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new(BucketSpec::SECONDS)
    }
}

impl Histogram {
    /// Creates a histogram with the given bucket layout.
    pub fn new(spec: BucketSpec) -> Self {
        Histogram {
            spec,
            buckets: (0..spec.n_buckets()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// The bucket layout this histogram was built with.
    pub fn spec(&self) -> BucketSpec {
        self.spec
    }

    /// Records one sample. NaN, infinite and negative samples are
    /// dropped (they would poison quantiles).
    pub fn record(&self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.buckets[self.spec.bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // CAS loops for the f64 aggregates; contention here is rare
        // (histograms are updated per span/request, not per coordinate).
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        update_extreme(&self.min_bits, v, |new, old| new < old);
        update_extreme(&self.max_bits, v, |new, old| new > old);
    }

    /// Records a [`std::time::Duration`] in seconds.
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_secs_f64());
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// A consistent-enough point-in-time summary.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = counts.iter().sum();
        let sum = f64::from_bits(self.sum_bits.load(Ordering::Relaxed));
        let min = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        let max = f64::from_bits(self.max_bits.load(Ordering::Relaxed));
        let quantile = |q: f64| -> f64 {
            if count == 0 {
                return f64::NAN;
            }
            let target = (q * count as f64).ceil().max(1.0) as u64;
            let mut seen = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                seen += c;
                if seen >= target {
                    // Clamp the bucket representative into the observed
                    // range so tiny histograms stay sensible.
                    return self.spec.bucket_mid(i).clamp(min, max);
                }
            }
            max
        };
        HistogramSnapshot {
            count,
            sum,
            mean: if count == 0 { f64::NAN } else { sum / count as f64 },
            min: if count == 0 { f64::NAN } else { min },
            max: if count == 0 { f64::NAN } else { max },
            p50: quantile(0.50),
            p90: quantile(0.90),
            p99: quantile(0.99),
        }
    }
}

fn update_extreme(bits: &AtomicU64, v: f64, better: impl Fn(f64, f64) -> bool) {
    let mut cur = bits.load(Ordering::Relaxed);
    while better(v, f64::from_bits(cur)) {
        match bits.compare_exchange_weak(
            cur,
            v.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// Point-in-time summary of one histogram.
#[derive(Debug, Clone, Copy)]
pub struct HistogramSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Sum of samples.
    pub sum: f64,
    /// Arithmetic mean (NaN when empty).
    pub mean: f64,
    /// Smallest sample (NaN when empty).
    pub min: f64,
    /// Largest sample (NaN when empty).
    pub max: f64,
    /// Estimated median.
    pub p50: f64,
    /// Estimated 90th percentile.
    pub p90: f64,
    /// Estimated 99th percentile.
    pub p99: f64,
}

/// The named-metric registry. One global instance lives behind
/// [`global`]; tests may build private ones.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Creates an empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// The counter named `name`, created on first use.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        lookup(&self.counters, name)
    }

    /// The gauge named `name`, created on first use.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        lookup(&self.gauges, name)
    }

    /// The histogram named `name`, created on first use with
    /// [`BucketSpec::SECONDS`] buckets.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        lookup_with(&self.histograms, name, || Histogram::new(BucketSpec::SECONDS))
    }

    /// The histogram named `name`, created on first use with the given
    /// bucket layout. A name's first registration wins: later callers
    /// (with any spec) get the existing histogram, so call sites that
    /// share a name must agree on its layout.
    pub fn histogram_with(&self, name: &str, spec: BucketSpec) -> Arc<Histogram> {
        lookup_with(&self.histograms, name, || Histogram::new(spec))
    }

    /// A point-in-time snapshot of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }

    /// Drops every registered metric (test isolation helper). Handles
    /// obtained before the reset keep working but are orphaned.
    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
        self.histograms.lock().unwrap().clear();
    }
}

fn lookup<T: Default>(map: &Mutex<BTreeMap<String, Arc<T>>>, name: &str) -> Arc<T> {
    lookup_with(map, name, T::default)
}

fn lookup_with<T>(
    map: &Mutex<BTreeMap<String, Arc<T>>>,
    name: &str,
    make: impl FnOnce() -> T,
) -> Arc<T> {
    let mut guard = map.lock().unwrap();
    if let Some(v) = guard.get(name) {
        return Arc::clone(v);
    }
    let v = Arc::new(make());
    guard.insert(name.to_string(), Arc::clone(&v));
    v
}

/// Snapshot of the whole registry (sorted names for stable rendering).
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram summaries by name.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// Renders the snapshot as a protocol [`Json`] object — the payload
    /// of the server's `{"cmd":"stats"}` response.
    ///
    /// [`Json`]: crate::coordinator::protocol::Json
    pub fn to_json(&self) -> crate::coordinator::protocol::Json {
        use crate::coordinator::protocol::Json;
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges.iter().map(|(k, &v)| (k.clone(), num(v))).collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count as f64)),
                            ("sum", num(h.sum)),
                            ("mean", num(h.mean)),
                            ("min", num(h.min)),
                            ("max", num(h.max)),
                            ("p50", num(h.p50)),
                            ("p90", num(h.p90)),
                            ("p99", num(h.p99)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }
}

/// The process-wide registry every instrumented layer reports into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("a.count");
        c.inc();
        c.add(4);
        assert_eq!(r.counter("a.count").get(), 5);
        let g = r.gauge("a.level");
        g.set(2.5);
        assert_eq!(r.gauge("a.level").get(), 2.5);
        // same name -> same underlying metric
        assert!(Arc::ptr_eq(&c, &r.counter("a.count")));
    }

    #[test]
    fn histogram_quantiles_are_order_of_magnitude_right() {
        let h = Histogram::default();
        for _ in 0..90 {
            h.record(1e-3); // 90 samples at ~1ms
        }
        for _ in 0..10 {
            h.record(1e-1); // 10 samples at ~100ms
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // log-bucket estimate: within a factor of sqrt(2) of the truth
        assert!(s.p50 >= 0.5e-3 && s.p50 <= 2e-3, "p50 {}", s.p50);
        assert!(s.p99 >= 0.5e-1 && s.p99 <= 2e-1, "p99 {}", s.p99);
        assert!((s.mean - (90.0 * 1e-3 + 10.0 * 1e-1) / 100.0).abs() < 1e-9);
        assert_eq!(s.min, 1e-3);
        assert_eq!(s.max, 1e-1);
    }

    #[test]
    fn histogram_ignores_non_finite_and_negative() {
        let h = Histogram::default();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-1.0);
        assert_eq!(h.count(), 0);
        let s = h.snapshot();
        assert!(s.p50.is_nan() && s.mean.is_nan());
        h.record(0.0); // zero is legal (fastest bucket)
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn bucket_index_monotone_and_clamped() {
        let spec = BucketSpec::SECONDS;
        assert_eq!(spec.bucket_index(0.0), 0);
        assert_eq!(spec.bucket_index(1e-12), 0);
        assert_eq!(spec.bucket_index(1e9), spec.n_buckets() - 1);
        let mut prev = 0;
        for e in -28..7 {
            let i = spec.bucket_index((e as f64).exp2());
            assert!(i >= prev, "bucket index must be monotone");
            prev = i;
        }
    }

    #[test]
    fn counts_spec_holds_large_values() {
        // The SECONDS layout tops out at 2^8; byte counts need COUNTS.
        let h = Histogram::new(BucketSpec::COUNTS);
        for _ in 0..90 {
            h.record(4096.0);
        }
        for _ in 0..10 {
            h.record(1_048_576.0);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // Power-of-two buckets: estimates within a factor of 2.
        assert!(s.p50 >= 2048.0 && s.p50 <= 8192.0, "p50 {}", s.p50);
        assert!(s.p99 >= 0.5e6 && s.p99 <= 2.1e6, "p99 {}", s.p99);
        assert_eq!(s.max, 1_048_576.0);
    }

    #[test]
    fn histogram_with_first_registration_wins() {
        let r = Registry::new();
        let h = r.histogram_with("batch.bytes", BucketSpec::COUNTS);
        assert_eq!(h.spec(), BucketSpec::COUNTS);
        // Later plain lookups return the same histogram, same layout.
        let again = r.histogram("batch.bytes");
        assert!(Arc::ptr_eq(&h, &again));
        assert_eq!(again.spec(), BucketSpec::COUNTS);
    }

    #[test]
    fn parallel_increments_sum_correctly() {
        // The concurrency contract: increments from many threads are
        // never lost (satellite test; the pool-driven variant lives in
        // rust/tests/telemetry.rs).
        let r = Arc::new(Registry::new());
        let threads: u64 = 8;
        let per_thread: u64 = 10_000;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let c = r.counter("contended");
                    let h = r.histogram("contended.seconds");
                    for i in 0..per_thread {
                        c.inc();
                        h.record(1e-6 * (1 + i % 7) as f64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("contended").get(), threads * per_thread);
        assert_eq!(r.histogram("contended.seconds").count(), threads * per_thread);
    }

    #[test]
    fn snapshot_to_json_encodes() {
        let r = Registry::new();
        r.counter("x").add(3);
        r.gauge("y").set(0.5);
        r.histogram("z").record(1e-3);
        let json = r.snapshot().to_json();
        let enc = json.encode();
        assert!(enc.contains("\"x\":3"), "{enc}");
        assert!(enc.contains("\"y\":0.5"), "{enc}");
        assert!(enc.contains("\"count\":1"), "{enc}");
        // NaN-free: empty histogram quantiles encode as null
        let r2 = Registry::new();
        let _ = r2.histogram("empty");
        let enc2 = r2.snapshot().to_json().encode();
        assert!(enc2.contains("\"mean\":null"), "{enc2}");
    }

    #[test]
    fn reset_clears_names() {
        let r = Registry::new();
        r.counter("gone").inc();
        r.reset();
        assert_eq!(r.snapshot().counters.len(), 0);
        assert_eq!(r.counter("gone").get(), 0);
    }
}
