//! The closed-form screening bound — Algorithm 1 with the three KKT
//! cases of Theorems 6.5, 6.7 and 6.9.
//!
//! `neg_min(f̂)` computes `−min_{θ∈K} θᵀf̂`; the keep test is
//! `max(neg_min(f̂), neg_min(−f̂)) ≥ 1` (Eq. 45/48). Everything is scalar
//! arithmetic over the [`SharedContext`] and the per-feature
//! [`FeatureStats`] — O(1) per feature after the O(nnz) stats panel.
//!
//! Numerical-safety policy: when a case's preconditions are numerically
//! degenerate (zero projections, undefined cosines) we fall back to the
//! **ball ∩ equality** bound (Theorem 6.7), which is always a valid
//! upper bound because it optimizes over a superset of `K`.

use super::precompute::{FeatureStats, SharedContext};

/// Tolerance for "the cosine equals −1" (case 1) — in exact arithmetic a
/// measure-zero event; in floats a tight window.
const COS_EPS: f64 = 1e-9;
/// Relative tolerance for treating a squared projection norm as zero.
const ZERO_EPS: f64 = 1e-14;

/// Which KKT case resolved a `neg_min` evaluation (for the T3 case-mix
/// ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundCase {
    /// `f̂` (after `P_y`) anti-parallel to the half-space normal (Thm 6.5).
    Colinear,
    /// Minimum interior to the half-space: ball ∩ equality (Thm 6.7).
    Ball,
    /// Minimum on ball ∩ half-space boundary (Thm 6.9, switched ball).
    Plane,
    /// Degenerate feature (`f̂ ∈ span(y)` or zero): bound is exact 0.
    Degenerate,
}

/// `−min_{θ∈K} θᵀf̂` plus the case that produced it.
pub fn neg_min_cased(ctx: &SharedContext, s: &FeatureStats) -> (f64, BoundCase) {
    // ‖P_y(f̂)‖²
    let pyf_sq = if ctx.ysq > 0.0 { (s.q - s.dy * s.dy / ctx.ysq).max(0.0) } else { s.q };
    if pyf_sq <= ZERO_EPS * s.q.max(1.0) {
        // f̂ ∈ span(y): θᵀf̂ = γ·θᵀy = 0 on the equality constraint.
        return (0.0, BoundCase::Degenerate);
    }

    // P_y(a)ᵀP_y(f̂) = aᵀf̂ − (aᵀy)(f̂ᵀy)/‖y‖²
    let a_f = ctx.a_f(s);
    let pya_pyf = if ctx.ysq > 0.0 { a_f - ctx.a_y * s.dy / ctx.ysq } else { a_f };

    // SIGN CORRECTION (see module docs): the half-space is
    // aᵀ(b + r) ≥ 0 (Eq. 31 with b + r = θ₂ − θ₁), not the paper's
    // printed aᵀ(b + r) ≤ 0. The case derivations hold for the normal
    // â = −a, so every condition below substitutes a → −a; the case-3
    // value is invariant (it only sees a through P_a projections).

    // Case 1 (Thm 6.5 / Eq. 65 with â): cos(P_y â, P_y f̂) = −1, i.e.
    // cos(P_y a, P_y f̂) = +1; value (‖P_y f̂‖/‖P_y â‖)·âᵀθ₁ = −(…)·aᵀθ₁.
    if ctx.has_a && ctx.pya_sq > ZERO_EPS {
        let denom = (ctx.pya_sq * pyf_sq).sqrt();
        if denom > 0.0 {
            let cos = pya_pyf / denom;
            if cos >= 1.0 - COS_EPS {
                let m = -(pyf_sq / ctx.pya_sq).sqrt() * ctx.a_t;
                return (m, BoundCase::Colinear);
            }
        }
    }

    // P_y(b)ᵀP_y(f̂)
    let b_f = ctx.b_f(s);
    let pyb_pyf = if ctx.ysq > 0.0 { b_f - ctx.b_y * s.dy / ctx.ysq } else { b_f };

    // Ball bound (Thm 6.7 / Eq. 83) — also the safe fallback.
    let ball = (ctx.pyb_sq * pyf_sq).sqrt() - pyb_pyf - s.dt;

    // Case 2 condition (Thm 6.7 with â):
    // P_y(â)ᵀ(P_y(b)/‖P_y(b)‖ − P_y(f̂)/‖P_y(f̂)‖) ≤ 0
    //   ⇔ P_y(a)ᵀP_y(f̂)/‖P_y(f̂)‖ ≤ P_y(a)ᵀP_y(b)/‖P_y(b)‖.
    // Degenerate geometry (no half-space, a ∥ y, or zero-radius ball)
    // falls back to the ball bound, which is safe by superset.
    let use_ball = if !ctx.has_a || ctx.pya_sq <= ZERO_EPS || ctx.pyb_sq <= ZERO_EPS {
        true
    } else {
        let cond = ctx.pya_pyb / ctx.pyb_sq.sqrt() - pya_pyf / pyf_sq.sqrt();
        cond >= 0.0
    };
    if use_ball {
        return (ball, BoundCase::Ball);
    }

    // Case 3 (Thm 6.9 / corrected Eq. 97): minimum on the intersection of
    // the (switched, Thm 6.2) ball and the half-space boundary.
    //   −min θᵀf̂ = ½(1/λ₂ − 1/λ₁)·( ‖P_{P_a y}(P_a f̂)‖·‖P_{P_a y}(P_a 1)‖
    //                                − P_{P_a y}(P_a 1)ᵀ P_{P_a y}(P_a f̂) )
    //              − f̂ᵀθ₁
    let paf_sq = (s.q - a_f * a_f).max(0.0);
    let paf_pay = s.dy - a_f * ctx.a_y;
    let paf_pa1 = s.d1 - a_f * ctx.a_1;
    let (ppf_sq, pp1_ppf) = if ctx.pay_sq > ZERO_EPS {
        (
            (paf_sq - paf_pay * paf_pay / ctx.pay_sq).max(0.0),
            paf_pa1 - paf_pay * ctx.pa1_pay / ctx.pay_sq,
        )
    } else {
        (paf_sq, paf_pa1)
    };
    let delta = 0.5 * (ctx.inv2 - ctx.inv1);
    let m = delta * ((ppf_sq * ctx.ppay_pa1_sq).sqrt() - pp1_ppf) - s.dt;
    (m, BoundCase::Plane)
}

/// `−min_{θ∈K} θᵀf̂` (Algorithm 1's `neg_min`).
pub fn neg_min(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    neg_min_cased(ctx, s).0
}

/// The screening bound `max_{θ∈K} |θᵀf̂| = max(neg_min(f̂), neg_min(−f̂))`
/// (Eq. 45/48). The feature is **kept** iff this is ≥ 1.
pub fn bound(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    neg_min(ctx, s).max(neg_min(ctx, &s.neg()))
}

/// Bound plus the two case tags (for the case-mix ablation).
pub fn bound_cased(ctx: &SharedContext, s: &FeatureStats) -> (f64, BoundCase, BoundCase) {
    let (m1, c1) = neg_min_cased(ctx, s);
    let (m2, c2) = neg_min_cased(ctx, &s.neg());
    (m1.max(m2), c1, c2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{Pcg32, SynthSpec};
    use crate::data::FeatureMatrix;
    use crate::screening::qcqp_ref::qcqp_neg_min;
    use crate::solver::api::{solve, SolveOptions, SolverKind};
    use crate::svm::problem::Problem;
    use crate::testkit::{assert_close, assert_dominates, property};

    /// Builds a context at lambda_max for a synthetic problem.
    fn ctx_at_lambda_max(p: &Problem, frac: f64) -> SharedContext {
        let theta1 = p.theta_at_lambda_max().theta();
        SharedContext::build(&p.y, &theta1, p.lambda_max(), frac * p.lambda_max()).unwrap()
    }

    #[test]
    fn bound_dominates_true_dual_correlation() {
        // The real safety property: bound >= |theta2' fhat| for the TRUE
        // optimal theta2, across datasets and lambda fractions.
        for (spec, fracs) in [
            (SynthSpec::dense(40, 30, 71), vec![0.9, 0.7, 0.5]),
            (SynthSpec::text(50, 80, 72), vec![0.9, 0.6]),
            (SynthSpec::corr(40, 30, 73), vec![0.8, 0.5]),
        ] {
            let p = Problem::from_dataset(&spec.generate());
            for &frac in &fracs {
                let lambda2 = frac * p.lambda_max();
                let ctx = ctx_at_lambda_max(&p, frac);
                // exact solve at lambda2
                let rep = solve(
                    SolverKind::Cd,
                    &p.x,
                    &p.y,
                    lambda2,
                    None,
                    &SolveOptions::precise(),
                )
                .unwrap();
                assert!(rep.converged, "{:?}", rep.gap);
                let theta2 = crate::svm::dual::theta_from_primal(
                    &p.x, &p.y, &rep.w, rep.b, lambda2,
                );
                let ytheta2: Vec<f64> =
                    p.y.iter().zip(&theta2).map(|(a, b)| a * b).collect();
                for j in 0..p.m() {
                    let s = crate::screening::FeatureStats::compute(
                        &p.x, j, &p.y, &ctx.ytheta1,
                    );
                    let u = bound(&ctx, &s);
                    let truth = p.x.col_dot(j, &ytheta2).abs();
                    assert_dominates(
                        u,
                        truth,
                        1e-5,
                        &format!("{} frac={frac} feature {j}", p.name),
                    );
                }
            }
        }
    }

    #[test]
    fn bound_matches_qcqp_reference() {
        // The closed form must equal the numerically-optimized bound.
        property("bound-vs-qcqp", 77, 12, |rng| {
            let n = 8 + rng.below(10);
            // random y with both classes
            let mut y: Vec<f64> =
                (0..n).map(|_| if rng.f64() < 0.5 { 1.0 } else { -1.0 }).collect();
            y[0] = 1.0;
            y[1] = -1.0;
            // theta1: nonneg, y-orthogonal-ish: project positives
            let mut theta1: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            // enforce theta1' y = 0 by rescaling the positive/negative parts
            let sp: f64 = theta1
                .iter()
                .zip(&y)
                .filter(|(_, &yi)| yi > 0.0)
                .map(|(t, _)| *t)
                .sum();
            let sn: f64 = theta1
                .iter()
                .zip(&y)
                .filter(|(_, &yi)| yi < 0.0)
                .map(|(t, _)| *t)
                .sum();
            if sp > 0.0 && sn > 0.0 {
                let target = 0.5 * (sp + sn);
                for (t, &yi) in theta1.iter_mut().zip(&y) {
                    *t *= if yi > 0.0 { target / sp } else { target / sn };
                }
            }
            let l1 = 1.0 + rng.f64();
            let l2 = l1 * (0.4 + 0.5 * rng.f64());
            let ctx = SharedContext::build(&y, &theta1, l1, l2).unwrap();
            // random feature
            let f: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let fhat: Vec<f64> = f.iter().zip(&y).map(|(v, yi)| v * yi).collect();
            let s = FeatureStats {
                dy: crate::linalg::dot(&fhat, &y),
                d1: crate::linalg::sum(&fhat),
                dt: crate::linalg::dot(&fhat, &theta1),
                q: crate::linalg::nrm2_sq(&fhat),
            };
            let closed = neg_min(&ctx, &s);
            let reference = qcqp_neg_min(&y, &theta1, l1, l2, &fhat);
            // reference is a maximization from (approximately) inside the
            // feasible set: closed >= reference up to Dykstra's
            // feasibility tolerance (points may overshoot the ball by
            // ~1e-7 relative, worth ~1e-5 in objective).
            assert_dominates(closed, reference - 1e-4, 1e-6, "closed >= qcqp");
            assert_close(closed, reference, 5e-3, "closed == qcqp");
        });
    }

    #[test]
    fn screening_tightens_as_lambda2_approaches_lambda1() {
        // Monotonicity of the geometry: the ball radius grows with the
        // lambda gap, so bounds (and thus kept sets) grow too.
        let p = Problem::from_dataset(&SynthSpec::text(60, 150, 79).generate());
        let count_kept = |frac: f64| -> usize {
            let ctx = ctx_at_lambda_max(&p, frac);
            (0..p.m())
                .filter(|&j| {
                    let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
                    bound(&ctx, &s) >= 1.0
                })
                .count()
        };
        let near = count_kept(0.95);
        let mid = count_kept(0.7);
        let far = count_kept(0.3);
        assert!(near <= mid && mid <= far, "kept {near} {mid} {far}");
        // near lambda_max almost everything should be screened
        assert!(near < p.m() / 4, "kept {near} of {}", p.m());
    }

    #[test]
    fn degenerate_feature_parallel_to_y() {
        // f = 1 (so fhat = y): bound must be exactly 0 -> screened.
        let n = 10;
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let theta1: Vec<f64> = vec![0.3; n];
        // make theta1' y = 0 (balanced, constant theta works)
        let ctx = SharedContext::build(&y, &theta1, 2.0, 1.0).unwrap();
        let fhat = y.clone(); // f = 1 => fhat = y
        let s = FeatureStats {
            dy: crate::linalg::nrm2_sq(&y),
            d1: crate::linalg::sum(&fhat),
            dt: crate::linalg::dot(&fhat, &theta1),
            q: crate::linalg::nrm2_sq(&fhat),
        };
        let (m, case) = neg_min_cased(&ctx, &s);
        assert_eq!(case, BoundCase::Degenerate);
        assert_eq!(m, 0.0);
    }

    #[test]
    fn case_mix_is_reported() {
        // At λ₁ = λ_max the half-space normal a ∝ y (θ₁ − 1/λ_max ∝ −y·b*),
        // so P_y(a) = 0 and everything resolves by the ball case. Use an
        // *interior* θ₁ so the Plane case can engage.
        let p = Problem::from_dataset(&SynthSpec::dense(40, 60, 81).generate());
        let l1 = 0.6 * p.lambda_max();
        let rep = solve(SolverKind::Cd, &p.x, &p.y, l1, None, &SolveOptions::precise())
            .unwrap();
        let theta1 = crate::svm::dual::theta_from_primal(&p.x, &p.y, &rep.w, rep.b, l1);
        let ctx = SharedContext::build(&p.y, &theta1, l1, 0.5 * l1).unwrap();
        let mut cases = std::collections::HashMap::new();
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            let (_, c1, c2) = bound_cased(&ctx, &s);
            *cases.entry(format!("{c1:?}")).or_insert(0) += 1;
            *cases.entry(format!("{c2:?}")).or_insert(0) += 1;
        }
        // Both non-degenerate branches should occur on generic data.
        let total: usize = cases.values().sum();
        assert_eq!(total, 2 * p.m());
        assert!(cases.len() >= 2, "only cases {cases:?}");
    }

    /// Forces the β>0, α>0 case (Thm 6.9): pick f̂ pointing into the
    /// spherical cap the half-space cuts off, so the unconstrained ball
    /// minimizer is infeasible and the minimum lands on the intersection.
    /// Validates the corrected Eq. (97) against the numerical QCQP.
    #[test]
    fn plane_case_matches_qcqp_reference() {
        property("plane-case-vs-qcqp", 87, 10, |rng| {
            let n = 10 + rng.below(8);
            let mut y: Vec<f64> =
                (0..n).map(|_| if rng.f64() < 0.5 { 1.0 } else { -1.0 }).collect();
            y[0] = 1.0;
            y[1] = -1.0;
            let mut theta1: Vec<f64> = (0..n).map(|_| 0.2 + rng.f64()).collect();
            let sp: f64 = theta1.iter().zip(&y).filter(|(_, &yi)| yi > 0.0).map(|(t, _)| *t).sum();
            let sn: f64 = theta1.iter().zip(&y).filter(|(_, &yi)| yi < 0.0).map(|(t, _)| *t).sum();
            let target = 0.5 * (sp + sn);
            for (t, &yi) in theta1.iter_mut().zip(&y) {
                *t *= if yi > 0.0 { target / sp } else { target / sn };
            }
            let l1 = 1.0 + rng.f64();
            let l2 = l1 * (0.5 + 0.3 * rng.f64());
            let ctx = SharedContext::build(&y, &theta1, l1, l2).unwrap();
            // fhat ≈ -(projected a) + noise: drives pya_pyf strongly
            // negative for +fhat... we want pya_pyf/|pyf| > pya_pyb/|pyb|,
            // i.e. fhat aligned WITH P_y(a). Try both signs and keep
            // whichever lands in the plane case.
            let a_raw: Vec<f64> = theta1.iter().map(|t| t - 1.0 / l1).collect();
            let na = crate::linalg::nrm2(&a_raw);
            if na < 1e-9 {
                return; // degenerate draw
            }
            let mut hit = false;
            for sign in [1.0, -1.0] {
                let fhat: Vec<f64> = a_raw
                    .iter()
                    .map(|v| sign * v / na + 0.2 * rng.gaussian())
                    .collect();
                let s = FeatureStats {
                    dy: crate::linalg::dot(&fhat, &y),
                    d1: crate::linalg::sum(&fhat),
                    dt: crate::linalg::dot(&fhat, &theta1),
                    q: crate::linalg::nrm2_sq(&fhat),
                };
                let (m, case) = neg_min_cased(&ctx, &s);
                if case == BoundCase::Plane {
                    hit = true;
                    let reference = qcqp_neg_min(&y, &theta1, l1, l2, &fhat);
                    assert_dominates(m, reference - 1e-6, 1e-6, "plane >= qcqp");
                    assert_close(m, reference, 1e-2, "plane == qcqp");
                }
            }
            // At least warn-by-fail if the construction never triggers:
            // tracked across the property's cases via the outer counter.
            let _ = hit;
        });
        // Deterministic construction that must hit the plane case:
        let y = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        let theta1 = vec![0.9, 0.3, 0.5, 0.7, 0.2, 0.6];
        // theta1'y = 0.9-0.3+0.5-0.7+0.2-0.6 = 0 ✓
        let (l1, l2) = (1.5, 1.0);
        let ctx = SharedContext::build(&y, &theta1, l1, l2).unwrap();
        let a_raw: Vec<f64> = theta1.iter().map(|t| t - 1.0 / l1).collect();
        let na = crate::linalg::nrm2(&a_raw);
        // Near-parallel to a (exact parallelism would hit the Colinear
        // branch); the perturbation keeps cos < 1 − eps so the minimum
        // lands on the ball ∩ half-space intersection (Plane).
        let fhat: Vec<f64> = a_raw
            .iter()
            .enumerate()
            .map(|(i, v)| v / na + if i % 2 == 0 { 0.15 } else { -0.1 })
            .collect();
        let s = FeatureStats {
            dy: crate::linalg::dot(&fhat, &y),
            d1: crate::linalg::sum(&fhat),
            dt: crate::linalg::dot(&fhat, &theta1),
            q: crate::linalg::nrm2_sq(&fhat),
        };
        let (m_pos, c_pos) = neg_min_cased(&ctx, &s);
        let (m_neg, c_neg) = neg_min_cased(&ctx, &s.neg());
        assert!(
            c_pos == BoundCase::Plane || c_neg == BoundCase::Plane,
            "constructed case should hit the plane branch: {c_pos:?}/{c_neg:?}"
        );
        for (m, c, sgn) in [(m_pos, c_pos, 1.0), (m_neg, c_neg, -1.0)] {
            if c == BoundCase::Plane {
                let f_signed: Vec<f64> = fhat.iter().map(|v| sgn * v).collect();
                let reference = qcqp_neg_min(&y, &theta1, l1, l2, &f_signed);
                assert_close(m, reference, 1e-2, "deterministic plane == qcqp");
            }
        }
    }

    #[test]
    fn negation_symmetry() {
        // bound(f) == bound(-f) by construction.
        let p = Problem::from_dataset(&SynthSpec::dense(30, 20, 83).generate());
        let ctx = ctx_at_lambda_max(&p, 0.55);
        let mut rng = Pcg32::seeded(85);
        for _ in 0..10 {
            let j = rng.below(20);
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            assert_close(bound(&ctx, &s), bound(&ctx, &s.neg()), 1e-12, "symmetry");
        }
    }
}
