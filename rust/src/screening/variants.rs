//! Screening-rule variants: ablation baselines and the unsafe heuristic.
//!
//! * [`ball_eq_bound`] — the ball ∩ equality bound (Theorem 6.7 applied
//!   unconditionally). Valid but looser than the full rule: it ignores
//!   the variational-inequality half-space. This isolates the
//!   contribution of the half-space (T3 ablation).
//! * [`sphere_bound`] — the plain Cauchy–Schwarz sphere test
//!   `|θᵀf̂| ≤ |cᵀf̂| + ‖b‖‖f̂‖`, ignoring both the half-space and the
//!   `θᵀy = 0` equality — the "static" baseline screening papers compare
//!   against.
//! * [`strong_keep`] — the (sequential) strong rule adapted to the SVM
//!   dual: keep iff `|f̂ᵀθ₁| ≥ 2λ₂/λ₁ − 1`. **Unsafe**: it can discard
//!   active features; T2 counts its violations.

use super::precompute::{FeatureStats, SharedContext};
use crate::linalg::proj_null_norm_sq;

/// Ball ∩ equality bound (Thm 6.7 formula used unconditionally):
/// `max(|θᵀf̂|) ≤ max over ±f̂ of √(‖P_y b‖²‖P_y f̂‖²) − P_y(b)ᵀP_y(f̂) − f̂ᵀθ₁`.
pub fn ball_eq_bound(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    let one_side = |s: &FeatureStats| -> f64 {
        let pyf_sq = proj_null_norm_sq(s.q, s.dy, ctx.ysq);
        let b_f = ctx.b_f(s);
        let pyb_pyf = if ctx.ysq > 0.0 { b_f - ctx.b_y * s.dy / ctx.ysq } else { b_f };
        (ctx.pyb_sq * pyf_sq).sqrt() - pyb_pyf - s.dt
    };
    one_side(s).max(one_side(&s.neg()))
}

/// Plain sphere test: `|θᵀf̂| ≤ |cᵀf̂| + ‖b‖·‖f̂‖` (no half-space, no
/// equality). The weakest safe bound.
pub fn sphere_bound(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    ctx.c_f(s).abs() + (ctx.b_sq * s.q).sqrt()
}

/// Strong-rule keep decision (unsafe heuristic): keep iff
/// `|f̂ᵀθ₁| ≥ 2λ₂/λ₁ − 1`.
///
/// Derivation: the lasso strong rule assumes the dual correlation
/// `|f̂ᵀα(λ)|` is 1-Lipschitz in λ; in θ-units at λ₂ that gives the
/// threshold `2λ₂/λ₁ − 1`.
pub fn strong_keep(ctx: &SharedContext, s: &FeatureStats) -> bool {
    let threshold = 2.0 * ctx.lambda2 / ctx.lambda1 - 1.0;
    s.dt.abs() >= threshold
}

/// A "bound-like" score for the strong rule so it can share reporting
/// code: ≥ 1 iff kept.
pub fn strong_score(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    let threshold = 2.0 * ctx.lambda2 / ctx.lambda1 - 1.0;
    if threshold <= 0.0 {
        // Gap too wide for the heuristic: keep everything.
        return f64::INFINITY;
    }
    s.dt.abs() / threshold
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::screening::paper;
    use crate::svm::problem::Problem;
    use crate::testkit::assert_dominates;

    fn setup(frac: f64) -> (Problem, SharedContext) {
        let p = Problem::from_dataset(&SynthSpec::dense(40, 50, 91).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let ctx =
            SharedContext::build(&p.y, &theta1, p.lambda_max(), frac * p.lambda_max())
                .unwrap();
        (p, ctx)
    }

    #[test]
    fn relaxations_are_ordered() {
        // paper bound <= ball∩eq bound <= sphere bound (superset chain).
        let (p, ctx) = setup(0.6);
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            let full = paper::bound(&ctx, &s);
            let ball = ball_eq_bound(&ctx, &s);
            let sphere = sphere_bound(&ctx, &s);
            assert_dominates(ball, full, 1e-9, &format!("ball >= paper, j={j}"));
            assert_dominates(sphere, ball, 1e-9, &format!("sphere >= ball, j={j}"));
        }
    }

    #[test]
    fn strong_rule_threshold_behaviour() {
        let (p, ctx) = setup(0.9);
        // threshold = 0.8: features with tiny correlation are dropped
        let mut kept = 0;
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            let keep = strong_keep(&ctx, &s);
            assert_eq!(keep, strong_score(&ctx, &s) >= 1.0);
            if keep {
                kept += 1;
            }
        }
        assert!(kept < p.m(), "strong rule should drop something at 0.9·λmax");
    }

    #[test]
    fn strong_rule_keeps_all_when_gap_wide() {
        let (p, ctx) = setup(0.3); // 2*0.3-1 < 0 -> keep all
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            assert!(strong_keep(&ctx, &s));
            assert_eq!(strong_score(&ctx, &s), f64::INFINITY);
        }
    }
}
