//! Screening-rule variants: ablation baselines and the unsafe heuristic.
//!
//! * [`ball_eq_bound`] — the ball ∩ equality bound (Theorem 6.7 applied
//!   unconditionally). Valid but looser than the full rule: it ignores
//!   the variational-inequality half-space. This isolates the
//!   contribution of the half-space (T3 ablation).
//! * [`sphere_bound`] — the plain Cauchy–Schwarz sphere test
//!   `|θᵀf̂| ≤ |cᵀf̂| + ‖b‖‖f̂‖`, ignoring both the half-space and the
//!   `θᵀy = 0` equality — the "static" baseline screening papers compare
//!   against.
//! * [`strong_keep`] — the (sequential) strong rule adapted to the SVM
//!   dual: keep iff `|f̂ᵀθ₁| ≥ 2λ₂/λ₁ − 1`. **Unsafe**: it can discard
//!   active features; T2 counts its violations.
//! * [`audit_screen`] — the safety-audit mode: re-checks every
//!   screened-out feature against the KKT condition `|θ₂ᵀf̂| ≤ 1` at the
//!   *converged* solution, generalizing T2's violation accounting from
//!   a bench-only check to a first-class, metered runtime mode
//!   (`--audit` on the CLI, `screening.violations` in telemetry).

use super::precompute::{FeatureStats, SharedContext};
use super::rule::{RuleKind, ScreenReport};
use crate::data::FeatureMatrix;
use crate::linalg::proj_null_norm_sq;

/// Ball ∩ equality bound (Thm 6.7 formula used unconditionally):
/// `max(|θᵀf̂|) ≤ max over ±f̂ of √(‖P_y b‖²‖P_y f̂‖²) − P_y(b)ᵀP_y(f̂) − f̂ᵀθ₁`.
pub fn ball_eq_bound(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    let one_side = |s: &FeatureStats| -> f64 {
        let pyf_sq = proj_null_norm_sq(s.q, s.dy, ctx.ysq);
        let b_f = ctx.b_f(s);
        let pyb_pyf = if ctx.ysq > 0.0 { b_f - ctx.b_y * s.dy / ctx.ysq } else { b_f };
        (ctx.pyb_sq * pyf_sq).sqrt() - pyb_pyf - s.dt
    };
    one_side(s).max(one_side(&s.neg()))
}

/// Plain sphere test: `|θᵀf̂| ≤ |cᵀf̂| + ‖b‖·‖f̂‖` (no half-space, no
/// equality). The weakest safe bound.
pub fn sphere_bound(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    ctx.c_f(s).abs() + (ctx.b_sq * s.q).sqrt()
}

/// Strong-rule keep decision (unsafe heuristic): keep iff
/// `|f̂ᵀθ₁| ≥ 2λ₂/λ₁ − 1`.
///
/// Derivation: the lasso strong rule assumes the dual correlation
/// `|f̂ᵀα(λ)|` is 1-Lipschitz in λ; in θ-units at λ₂ that gives the
/// threshold `2λ₂/λ₁ − 1`.
pub fn strong_keep(ctx: &SharedContext, s: &FeatureStats) -> bool {
    let threshold = 2.0 * ctx.lambda2 / ctx.lambda1 - 1.0;
    s.dt.abs() >= threshold
}

/// A "bound-like" score for the strong rule so it can share reporting
/// code: ≥ 1 iff kept.
pub fn strong_score(ctx: &SharedContext, s: &FeatureStats) -> f64 {
    let threshold = 2.0 * ctx.lambda2 / ctx.lambda1 - 1.0;
    if threshold <= 0.0 {
        // Gap too wide for the heuristic: keep everything.
        return f64::INFINITY;
    }
    s.dt.abs() / threshold
}

/// One screened-out feature that fails the KKT check at convergence.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Feature index.
    pub feature: usize,
    /// `|θ₂ᵀf̂|` at the converged solution (> 1 means active).
    pub correlation: f64,
    /// The feature's primal weight (0 when excluded from the solve).
    pub weight: f64,
}

/// Result of one safety audit: every screened-out feature of a
/// [`ScreenReport`], re-checked against the converged solution.
#[derive(Debug, Clone)]
pub struct AuditReport {
    /// Rule that produced the screening decision.
    pub rule: RuleKind,
    /// The λ the screening targeted (and the solve converged at).
    pub lambda2: f64,
    /// Screened-out features checked.
    pub checked: usize,
    /// KKT tolerance used (`|θ₂ᵀf̂| > 1 + tol` flags a violation).
    pub tol: f64,
    /// Violations found (empty for a safe rule, barring solver error).
    pub violations: Vec<Violation>,
}

impl AuditReport {
    /// Whether the audit found no violations.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Safety audit: given the *converged* primal `(w, b)` at
/// `report.lambda2`, maps it to the dual `θ₂` (Eq. 20) and verifies the
/// KKT inactivity condition `|θ₂ᵀf̂_j| ≤ 1 + tol` for every feature the
/// rule screened out. A violation means screening discarded a feature
/// that is active at the optimum — impossible for a safe rule with an
/// exact `θ₁`, so any hit flags either an unsafe heuristic or a solver
/// tolerance problem. Findings are metered (`screening.violations`,
/// `screening.audit.*`) and each violation emits an error-level event.
pub fn audit_screen<X: FeatureMatrix>(
    x: &X,
    y: &[f64],
    report: &ScreenReport,
    w: &[f64],
    b: f64,
    tol: f64,
) -> AuditReport {
    let theta = crate::svm::dual::theta_from_primal(x, y, w, b, report.lambda2);
    let ytheta: Vec<f64> = y.iter().zip(&theta).map(|(yi, ti)| yi * ti).collect();
    let mut checked = 0usize;
    let mut violations = Vec::new();
    for (j, &keep) in report.keep.iter().enumerate() {
        if keep {
            continue;
        }
        checked += 1;
        // f̂ᵀθ = (Yf)ᵀθ = fᵀ(y∘θ).
        let correlation = x.col_dot(j, &ytheta).abs();
        if correlation > 1.0 + tol {
            violations.push(Violation {
                feature: j,
                correlation,
                weight: w.get(j).copied().unwrap_or(0.0),
            });
        }
    }
    let tele = crate::telemetry::global();
    tele.counter("screening.audit.runs").inc();
    tele.counter("screening.audit.checked").add(checked as u64);
    // Touch the violations counter even when clean so a zero shows up
    // in `{"cmd":"stats"}` snapshots — "audited, found nothing" must be
    // distinguishable from "never audited".
    let viol_counter = tele.counter("screening.violations");
    if !violations.is_empty() {
        viol_counter.add(violations.len() as u64);
        for v in &violations {
            crate::tele_error!(
                "screening.audit",
                "rule {} screened ACTIVE feature {} at lambda {:.4e}: \
                 |theta'f|={:.6} w={:.3e}",
                report.rule.name(),
                v.feature,
                report.lambda2,
                v.correlation,
                v.weight
            );
        }
    }
    let audit =
        AuditReport { rule: report.rule, lambda2: report.lambda2, checked, tol, violations };
    // Violations are provenance too: when the ledger is on, each one
    // lands as a `source:"audit"` verdict (bound = the measured KKT
    // correlation, threshold = 1).
    crate::diag::ledger::global().record_audit(report, &audit);
    audit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::screening::paper;
    use crate::svm::problem::Problem;
    use crate::testkit::assert_dominates;

    fn setup(frac: f64) -> (Problem, SharedContext) {
        let p = Problem::from_dataset(&SynthSpec::dense(40, 50, 91).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let ctx =
            SharedContext::build(&p.y, &theta1, p.lambda_max(), frac * p.lambda_max())
                .unwrap();
        (p, ctx)
    }

    #[test]
    fn relaxations_are_ordered() {
        // paper bound <= ball∩eq bound <= sphere bound (superset chain).
        let (p, ctx) = setup(0.6);
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            let full = paper::bound(&ctx, &s);
            let ball = ball_eq_bound(&ctx, &s);
            let sphere = sphere_bound(&ctx, &s);
            assert_dominates(ball, full, 1e-9, &format!("ball >= paper, j={j}"));
            assert_dominates(sphere, ball, 1e-9, &format!("sphere >= ball, j={j}"));
        }
    }

    #[test]
    fn strong_rule_threshold_behaviour() {
        let (p, ctx) = setup(0.9);
        // threshold = 0.8: features with tiny correlation are dropped
        let mut kept = 0;
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            let keep = strong_keep(&ctx, &s);
            assert_eq!(keep, strong_score(&ctx, &s) >= 1.0);
            if keep {
                kept += 1;
            }
        }
        assert!(kept < p.m(), "strong rule should drop something at 0.9·λmax");
    }

    #[test]
    fn strong_rule_keeps_all_when_gap_wide() {
        let (p, ctx) = setup(0.3); // 2*0.3-1 < 0 -> keep all
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            assert!(strong_keep(&ctx, &s));
            assert_eq!(strong_score(&ctx, &s), f64::INFINITY);
        }
    }

    #[test]
    fn audit_clean_for_safe_rule() {
        use crate::screening::rule::screen_all;
        use crate::solver::api::{solve, SolveOptions, SolverKind};
        let p = Problem::from_dataset(&SynthSpec::text(50, 120, 121).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        let l2 = 0.6 * l1;
        let report =
            screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, l1, l2).unwrap();
        assert!(report.n_screened() > 0, "need something to audit");
        let sol =
            solve(SolverKind::Cd, &p.x, &p.y, l2, None, &SolveOptions::precise())
                .unwrap();
        let audit = audit_screen(&p.x, &p.y, &report, &sol.w, sol.b, 1e-4);
        assert!(audit.is_clean(), "violations: {:?}", audit.violations);
        assert_eq!(audit.checked, report.n_screened());
        assert_eq!(audit.rule, RuleKind::Paper);
    }

    #[test]
    fn audit_flags_doctored_report() {
        use crate::solver::api::{solve, SolveOptions, SolverKind};
        let p = Problem::from_dataset(&SynthSpec::text(50, 120, 123).generate());
        let l2 = 0.3 * p.lambda_max();
        let sol =
            solve(SolverKind::Cd, &p.x, &p.y, l2, None, &SolveOptions::precise())
                .unwrap();
        // Forge a report that claims an *active* feature was screened out.
        let active = (0..p.m())
            .max_by(|&a, &b| {
                sol.w[a].abs().partial_cmp(&sol.w[b].abs()).unwrap()
            })
            .unwrap();
        assert!(sol.w[active].abs() > 1e-6, "need an active feature");
        let mut keep = vec![true; p.m()];
        keep[active] = false;
        let forged = ScreenReport {
            rule: RuleKind::Strong,
            lambda1: p.lambda_max(),
            lambda2: l2,
            keep,
            bounds: vec![f64::INFINITY; p.m()],
            seconds: 0.0,
        };
        // Re-solve honoring the forged screening (the active feature is
        // excluded): at *that* optimum the KKT correlation of the missing
        // feature exceeds 1, which is exactly what the audit must catch.
        let kept: Vec<usize> = (0..p.m()).filter(|&j| j != active).collect();
        let red =
            crate::solver::reduced::ReducedProblem::build(&p.x, kept).unwrap();
        let red_sol = red
            .solve(SolverKind::Cd, &p.y, l2, None, &SolveOptions::precise())
            .unwrap();
        let before =
            crate::telemetry::global().counter("screening.violations").get();
        let audit = audit_screen(&p.x, &p.y, &forged, &red_sol.w, red_sol.b, 1e-4);
        assert_eq!(audit.checked, 1);
        assert_eq!(audit.violations.len(), 1);
        assert_eq!(audit.violations[0].feature, active);
        assert!(audit.violations[0].correlation > 1.0);
        let after =
            crate::telemetry::global().counter("screening.violations").get();
        assert!(after >= before + 1, "violation counter must advance");
    }
}
