//! The paper's contribution: safe feature screening for the sparse SVM.
//!
//! Given a solved dual point `(λ₁, θ₁)` and a target `λ₂ < λ₁`, the rule
//! upper-bounds `max_{θ∈K} |θᵀf̂_j|` for every feature over the convex set
//!
//! ```text
//! K = { θ : ‖θ − c‖ ≤ ‖b‖,  aᵀ(θ − θ₁) ≥ 0,  θᵀy = 0 }
//! a ∝ θ₁ − 1/λ₁,  b = ½(1/λ₂ − θ₁),  c = ½(1/λ₂ + θ₁)      (Eq. 43)
//! ```
//!
//! and discards every feature whose bound is < 1 (necessary condition
//! for activity, Eq. 22). The bound has three closed-form KKT cases
//! (Theorems 6.5 / 6.7 / 6.9), implemented in [`paper`] on top of the
//! shared precompute in [`precompute`].
//!
//! * [`precompute`] — shared scalars + the per-feature statistics panel.
//! * [`paper`] — the 3-case `neg_min` bound and its case selector.
//! * [`variants`] — ball-only and plain-sphere relaxations (ablation
//!   baselines) and the *unsafe* strong rule.
//! * [`rule`] — the [`rule::ScreeningRule`] façade used by the path
//!   runner and the coordinator.
//! * [`qcqp_ref`] — slow numerical reference optimizer for the bound
//!   (tests only: certifies the closed forms).
//!
//! ## Two corrections to the paper's printed formulas
//!
//! Both are verified against the numerical reference (`qcqp_ref`) and the
//! end-to-end safety tests; the printed forms are not valid bounds.
//!
//! **1. Half-space sign (Eq. 43 rewrite / Algorithm 1 conditions).**
//! Since `θ₂ − θ₁ = b + r` identically (with `b = ½(1/λ₂·1 − θ₁)`,
//! `c = ½(1/λ₂·1 + θ₁)`, `θ₂ = c + r`), the variational-inequality
//! half-space of Eq. (31), `(θ₁ − 1/λ₁)ᵀ(θ₂ − θ₁) ≥ 0`, reads
//! `aᵀ(b + r) ≥ 0` — the paper's rewritten set K (and the §6.3–6.6
//! case analysis built on it) uses `aᵀ(b + r) ≤ 0`, the wrong side.
//! The case derivations are valid for the constraint `âᵀ(b + r) ≤ 0`
//! with `â = −a`, so we substitute `a → −a` in the case conditions and
//! the Thm 6.5 value; the Thm 6.9 value only sees `a` through `P_a`
//! projections and is sign-invariant. With the printed sign, the
//! half-space-binding formulas bound the wrong region of the ball (and
//! in practice the binding case essentially never triggers, silently
//! reducing the rule to the ball test).
//!
//! **2. Eq. (97) term placement.** The paper prints the `f̂ᵀθ₁` term
//! *inside* the `½(1/λ₂ − 1/λ₁)(·)` bracket. Re-deriving from Eq. (96)
//! and `ĉ = ½(1/λ₂ − 1/λ₁)P_a(1) + θ₁` puts it outside:
//!
//! ```text
//! −min θᵀf̂ = ½(1/λ₂−1/λ₁)·(‖P_{P_a(y)}(P_a f̂)‖‖P_{P_a(y)}(P_a 1)‖
//!                           − P_{P_a(y)}(P_a 1)ᵀ P_{P_a(y)}(P_a f̂))
//!            − f̂ᵀθ₁
//! ```

pub mod gapball;
pub mod paper;
pub mod precompute;
pub mod qcqp_ref;
pub mod rule;
pub mod variants;

pub use gapball::gap_ball_bounds;
pub use precompute::{FeatureStats, SharedContext};
pub use rule::{screen_all, RuleKind, ScreenReport, ScreeningRule};
