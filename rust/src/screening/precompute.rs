//! Shared precompute for the screening bound.
//!
//! §6.4/§6.5 of the paper observe that everything in the bound except
//! `f̂ᵀθ₁`, `f̂ᵀy`, `f̂ᵀ1`, `‖f̂‖²` is either independent of the feature
//! (functions of λ₁, λ₂, θ₁, y, 1 alone) or derivable from those four
//! dots. [`SharedContext`] materializes the feature-independent scalars
//! once; [`FeatureStats`] carries the four per-feature dots (produced by
//! [`crate::data::FeatureMatrix::col_dot4`] natively, or by the Pallas
//! panel kernel on the PJRT path).

use crate::data::cache::FeatureCache;
use crate::data::FeatureMatrix;
use crate::error::{Error, Result};
use crate::linalg::{proj_null_dot, proj_null_norm_sq};

/// The four per-feature dots the bound consumes.
///
/// For the weighted feature `f̂ = Y f`: `dy = f̂ᵀy = fᵀ1`-weighted... no —
/// all dots here are against the *weighted* feature:
/// `dy = f̂ᵀy`, `d1 = f̂ᵀ1`, `dt = f̂ᵀθ₁`, `q = ‖f̂‖² (= ‖f‖²)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeatureStats {
    /// `f̂ᵀ y`.
    pub dy: f64,
    /// `f̂ᵀ 1`.
    pub d1: f64,
    /// `f̂ᵀ θ₁`.
    pub dt: f64,
    /// `‖f̂‖²`.
    pub q: f64,
}

impl FeatureStats {
    /// Stats of `−f̂` (the squared norm is invariant).
    #[inline]
    pub fn neg(&self) -> FeatureStats {
        FeatureStats { dy: -self.dy, d1: -self.d1, dt: -self.dt, q: self.q }
    }

    /// Computes the stats for feature `j` natively.
    ///
    /// Since `f̂ = Yf` and `Y² = I`:
    /// `f̂ᵀy = fᵀ(Y y) = fᵀ1`… careful: `f̂ᵀy = (Yf)ᵀy = fᵀYy = fᵀ1²…`
    /// elementwise `Yy = y∘y = 1`, so `f̂ᵀy = fᵀ1`; similarly
    /// `f̂ᵀ1 = fᵀy` and `f̂ᵀθ₁ = fᵀ(y∘θ₁)`. One pass over the raw column
    /// with [`FeatureMatrix::col_dot4`] against `(y, ·, y∘θ₁)` yields all
    /// four.
    pub fn compute<X: FeatureMatrix>(x: &X, j: usize, y: &[f64], ytheta1: &[f64]) -> Self {
        // col_dot4 returns (f·y, f·1, f·ytheta1, ‖f‖²)
        let (f_y, f_1, f_yt, q) = x.col_dot4(j, y, ytheta1);
        FeatureStats { dy: f_1, d1: f_y, dt: f_yt, q }
    }

    /// [`FeatureStats::compute`] with the λ/θ-independent stats served
    /// from a [`FeatureCache`]: one θ-dependent dot (`fᵀ(y∘θ₁)`) instead
    /// of the four-way panel. Bit-identical to `compute` — the cache
    /// stores `col_dot4`'s own accumulators and the θ-dot uses the
    /// in-order [`FeatureMatrix::col_dot_seq`] (see the cache module
    /// docs for the contract).
    pub fn from_cache<X: FeatureMatrix>(
        x: &X,
        cache: &FeatureCache,
        j: usize,
        ytheta1: &[f64],
    ) -> Self {
        // Same mapping as `compute`: dy = f̂ᵀy = fᵀ1, d1 = f̂ᵀ1 = fᵀy.
        FeatureStats {
            dy: cache.dot_one[j],
            d1: cache.dot_y[j],
            dt: x.col_dot_seq(j, ytheta1),
            q: cache.norm_sq[j],
        }
    }
}

/// Feature-independent scalars for one `(λ₁, θ₁) → λ₂` screening step.
#[derive(Debug, Clone)]
pub struct SharedContext {
    /// Source λ (the solved one).
    pub lambda1: f64,
    /// Target λ (the one being screened for).
    pub lambda2: f64,
    /// `1/λ₁`.
    pub inv1: f64,
    /// `1/λ₂`.
    pub inv2: f64,
    /// Number of samples `n = ‖1‖²`.
    pub n: f64,
    /// `yᵀ1`.
    pub y1: f64,
    /// `‖y‖²` (= n for ±1 labels, kept general).
    pub ysq: f64,
    /// `θ₁ᵀ1`.
    pub t_sum: f64,
    /// `θ₁ᵀy` (0 at an exact dual point; kept for robustness).
    pub t_y: f64,
    /// `‖θ₁‖²`.
    pub t_sq: f64,
    /// `‖θ₁ − 1/λ₁·1‖` — the normalizer of `a`. May be 0 (see `has_a`).
    pub na: f64,
    /// Whether the half-space normal `a` is well-defined (`na > 0`).
    pub has_a: bool,
    /// `aᵀy`, `aᵀ1`, `aᵀθ₁` (all 0 when `!has_a`).
    pub a_y: f64,
    /// `aᵀ1`.
    pub a_1: f64,
    /// `aᵀθ₁`.
    pub a_t: f64,
    /// `bᵀy` where `b = ½(1/λ₂·1 − θ₁)`.
    pub b_y: f64,
    /// `bᵀθ₁`.
    pub b_t: f64,
    /// `‖b‖²`.
    pub b_sq: f64,
    /// `‖P_y(a)‖²`.
    pub pya_sq: f64,
    /// `‖P_y(b)‖²`.
    pub pyb_sq: f64,
    /// `P_y(a)ᵀP_y(b)`.
    pub pya_pyb: f64,
    /// `P_a(y)ᵀP_a(y)`.
    pub pay_sq: f64,
    /// `P_a(1)ᵀP_a(1)`.
    pub pa1_sq: f64,
    /// `P_a(1)ᵀP_a(y)`.
    pub pa1_pay: f64,
    /// `‖P_{P_a(y)}(P_a(1))‖²`.
    pub ppay_pa1_sq: f64,
    /// Copy of `y∘θ₁` for building per-feature stats.
    pub ytheta1: Vec<f64>,
}

impl SharedContext {
    /// Builds the context. `theta1` must be the dual point at `lambda1`
    /// (`θ = α/λ`, Eq. 20), and `lambda_max ≥ lambda1 > lambda2 > 0`.
    pub fn build(y: &[f64], theta1: &[f64], lambda1: f64, lambda2: f64) -> Result<Self> {
        if !(lambda1 > lambda2 && lambda2 > 0.0) {
            return Err(Error::screening(format!(
                "need lambda1 > lambda2 > 0, got {lambda1} vs {lambda2}"
            )));
        }
        if y.len() != theta1.len() {
            return Err(Error::screening("y / theta1 length mismatch"));
        }
        let n = y.len() as f64;
        let inv1 = 1.0 / lambda1;
        let inv2 = 1.0 / lambda2;
        // All sums are computed over the *elementwise* expressions rather
        // than expanded polynomials in the raw moments: the expansions
        // (e.g. ‖θ₁ − inv1·1‖² = t_sq − 2·inv1·t_sum + inv1²·n) cancel
        // catastrophically when θ₁ ≈ inv1·1, which genuinely happens at
        // λ₁ = λ_max with near-balanced classes.
        let mut y1 = 0.0;
        let mut ysq = 0.0;
        let mut t_sum = 0.0;
        let mut t_y = 0.0;
        let mut t_sq = 0.0;
        let mut na_sq = 0.0; // ‖θ₁ − inv1·1‖²
        let mut ar_y = 0.0; // (θ₁ − inv1·1)ᵀ y
        let mut ar_1 = 0.0; // (θ₁ − inv1·1)ᵀ 1
        let mut ar_t = 0.0; // (θ₁ − inv1·1)ᵀ θ₁
        let mut b_y = 0.0; // bᵀy,  b = ½(inv2·1 − θ₁)
        let mut b_t = 0.0; // bᵀθ₁
        let mut b_sq = 0.0; // ‖b‖²
        let mut ar_b = 0.0; // (θ₁ − inv1·1)ᵀ b
        for i in 0..y.len() {
            let yi = y[i];
            let ti = theta1[i];
            let ai = ti - inv1;
            let bi = 0.5 * (inv2 - ti);
            y1 += yi;
            ysq += yi * yi;
            t_sum += ti;
            t_y += ti * yi;
            t_sq += ti * ti;
            na_sq += ai * ai;
            ar_y += ai * yi;
            ar_1 += ai;
            ar_t += ai * ti;
            b_y += bi * yi;
            b_t += bi * ti;
            b_sq += bi * bi;
            ar_b += ai * bi;
        }
        let na = na_sq.sqrt();
        let has_a = na > 1e-12 * (1.0 + inv1 * n.sqrt());
        let (a_y, a_1, a_t, a_b) = if has_a {
            (ar_y / na, ar_1 / na, ar_t / na, ar_b / na)
        } else {
            (0.0, 0.0, 0.0, 0.0)
        };

        let pya_sq = proj_null_norm_sq(if has_a { 1.0 } else { 0.0 }, a_y, ysq);
        let pyb_sq = proj_null_norm_sq(b_sq, b_y, ysq);
        let pya_pyb = proj_null_dot(a_b, a_y, b_y, ysq);

        // P_a projections (a is unit when it exists).
        let (pay_sq, pa1_sq, pa1_pay) = if has_a {
            (
                (ysq - a_y * a_y).max(0.0),
                (n - a_1 * a_1).max(0.0),
                y1 - a_1 * a_y,
            )
        } else {
            (ysq, n, y1)
        };
        let ppay_pa1_sq = proj_null_norm_sq(pa1_sq, pa1_pay, pay_sq);

        Ok(SharedContext {
            lambda1,
            lambda2,
            inv1,
            inv2,
            n,
            y1,
            ysq,
            t_sum,
            t_y,
            t_sq,
            na,
            has_a,
            a_y,
            a_1,
            a_t,
            b_y,
            b_t,
            b_sq,
            pya_sq,
            pyb_sq,
            pya_pyb,
            pay_sq,
            pa1_sq,
            pa1_pay,
            ppay_pa1_sq,
            ytheta1: y.iter().zip(theta1).map(|(yi, ti)| yi * ti).collect(),
        })
    }

    /// Derived per-feature scalars: `aᵀf̂` from the stats panel.
    #[inline]
    pub fn a_f(&self, s: &FeatureStats) -> f64 {
        if self.has_a {
            (s.dt - self.inv1 * s.d1) / self.na
        } else {
            0.0
        }
    }

    /// `bᵀf̂ = ½(1/λ₂·f̂ᵀ1 − f̂ᵀθ₁)`.
    #[inline]
    pub fn b_f(&self, s: &FeatureStats) -> f64 {
        0.5 * (self.inv2 * s.d1 - s.dt)
    }

    /// `cᵀf̂ = ½(1/λ₂·f̂ᵀ1 + f̂ᵀθ₁)`.
    #[inline]
    pub fn c_f(&self, s: &FeatureStats) -> f64 {
        0.5 * (self.inv2 * s.d1 + s.dt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::data::FeatureMatrix;
    use crate::linalg::{dot, nrm2_sq, proj_null};
    use crate::svm::problem::Problem;
    use crate::testkit::assert_close;

    /// Brute-force context quantities from materialized vectors.
    fn check_against_materialized(
        y: &[f64],
        theta1: &[f64],
        l1: f64,
        l2: f64,
    ) {
        let ctx = SharedContext::build(y, theta1, l1, l2).unwrap();
        let n = y.len();
        let ones = vec![1.0; n];
        let a_raw: Vec<f64> = theta1.iter().map(|t| t - 1.0 / l1).collect();
        let na = nrm2_sq(&a_raw).sqrt();
        assert_close(ctx.na, na, 1e-12, "na");
        if na > 1e-10 {
            let a: Vec<f64> = a_raw.iter().map(|v| v / na).collect();
            assert_close(ctx.a_y, dot(&a, y), 1e-10, "a.y");
            assert_close(ctx.a_1, dot(&a, &ones), 1e-10, "a.1");
            assert_close(ctx.a_t, dot(&a, theta1), 1e-10, "a.theta1");
            let pya = proj_null(y, &a);
            assert_close(ctx.pya_sq, nrm2_sq(&pya), 1e-10, "‖P_y a‖²");
            let pay = proj_null(&a, y);
            let pa1 = proj_null(&a, &ones);
            assert_close(ctx.pay_sq, nrm2_sq(&pay), 1e-9, "‖P_a y‖²");
            assert_close(ctx.pa1_sq, nrm2_sq(&pa1), 1e-9, "‖P_a 1‖²");
            assert_close(ctx.pa1_pay, dot(&pa1, &pay), 1e-9, "P_a1 · P_a y");
            let pp = proj_null(&pay, &pa1);
            assert_close(ctx.ppay_pa1_sq, nrm2_sq(&pp), 1e-9, "‖P_Pay Pa1‖²");
        }
        let b: Vec<f64> = theta1.iter().map(|t| 0.5 * (1.0 / l2 - t)).collect();
        assert_close(ctx.b_sq, nrm2_sq(&b), 1e-10, "‖b‖²");
        assert_close(ctx.b_y, dot(&b, y), 1e-10, "b.y");
        let pyb = proj_null(y, &b);
        assert_close(ctx.pyb_sq, nrm2_sq(&pyb), 1e-10, "‖P_y b‖²");
        if na > 1e-10 {
            let a: Vec<f64> = a_raw.iter().map(|v| v / na).collect();
            let pya = proj_null(y, &a);
            assert_close(ctx.pya_pyb, dot(&pya, &pyb), 1e-10, "P_y a · P_y b");
        }
    }

    #[test]
    fn context_matches_materialized_at_lambda_max() {
        let ds = SynthSpec::dense(30, 10, 61).generate();
        let p = Problem::from_dataset(&ds);
        let dp = p.theta_at_lambda_max();
        let theta1 = dp.theta();
        let l1 = p.lambda_max();
        check_against_materialized(&p.y, &theta1, l1, 0.6 * l1);
    }

    #[test]
    fn context_matches_materialized_generic_theta() {
        // Arbitrary (not-even-feasible) theta1 exercises the algebra.
        let y: Vec<f64> = (0..15).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let theta1: Vec<f64> = (0..15).map(|i| 0.1 + 0.02 * i as f64).collect();
        check_against_materialized(&y, &theta1, 2.0, 1.2);
    }

    #[test]
    fn per_feature_derivations() {
        let ds = SynthSpec::dense(25, 8, 63).generate();
        let p = Problem::from_dataset(&ds);
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        let ctx = SharedContext::build(&p.y, &theta1, l1, 0.5 * l1).unwrap();
        let ones = vec![1.0; 25];
        for j in 0..8 {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            // materialize fhat = Y f
            let mut f = vec![0.0; 25];
            p.x.densify_col(j, &mut f);
            let fhat: Vec<f64> = f.iter().zip(&p.y).map(|(v, yi)| v * yi).collect();
            assert_close(s.dy, dot(&fhat, &p.y), 1e-10, "dy");
            assert_close(s.d1, dot(&fhat, &ones), 1e-10, "d1");
            assert_close(s.dt, dot(&fhat, &theta1), 1e-10, "dt");
            assert_close(s.q, nrm2_sq(&fhat), 1e-10, "q");
            // derived
            let a_raw: Vec<f64> = theta1.iter().map(|t| t - 1.0 / l1).collect();
            let na = nrm2_sq(&a_raw).sqrt();
            let a: Vec<f64> = a_raw.iter().map(|v| v / na).collect();
            assert_close(ctx.a_f(&s), dot(&a, &fhat), 1e-9, "a.fhat");
            let b: Vec<f64> = theta1.iter().map(|t| 0.5 * (ctx.inv2 - t)).collect();
            assert_close(ctx.b_f(&s), dot(&b, &fhat), 1e-9, "b.fhat");
            let c: Vec<f64> = theta1.iter().map(|t| 0.5 * (ctx.inv2 + t)).collect();
            assert_close(ctx.c_f(&s), dot(&c, &fhat), 1e-9, "c.fhat");
            // negation flips the linear stats
            let neg = s.neg();
            assert_eq!(neg.q, s.q);
            assert_eq!(neg.dy, -s.dy);
        }
    }

    #[test]
    fn rejects_bad_lambdas() {
        let y = vec![1.0, -1.0];
        let t = vec![0.5, 0.5];
        assert!(SharedContext::build(&y, &t, 1.0, 1.0).is_err());
        assert!(SharedContext::build(&y, &t, 1.0, 2.0).is_err());
        assert!(SharedContext::build(&y, &t, 1.0, 0.0).is_err());
        assert!(SharedContext::build(&y, &t[..1], 1.0, 0.5).is_err());
    }

    #[test]
    fn degenerate_a_detected() {
        // theta1 exactly 1/lambda1 -> a undefined -> has_a = false.
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let t = vec![0.5; 4];
        let ctx = SharedContext::build(&y, &t, 2.0, 1.0).unwrap();
        assert!(!ctx.has_a);
        assert_eq!(ctx.a_y, 0.0);
    }
}
