//! Dynamic (gap-ball) screening — the extension the sequential rule
//! points toward (Bonnefoy et al. 2014; Fercoq et al. 2015 for lasso).
//!
//! The paper's rule needs a *solved* dual point at some λ₁ > λ₂. But the
//! dual objective `D(α) = 1ᵀα − ½‖α‖²` is 1-strongly concave, so any
//! dual-feasible `α̂` at the *current* λ certifies
//!
//! ```text
//! ‖α* − α̂‖² ≤ 2·(P(w) − D(α̂)) = 2·gap      ⇒ with θ = α/λ:
//! |θ*ᵀf̂| ≤ |θ̂ᵀf̂| + ‖f̂‖·√(2·gap)/λ
//! ```
//!
//! — a *safe* bound that tightens as the solver converges. The CD solver
//! applies it at every gap check (`SolveOptions::dynamic_screen`),
//! freezing coordinates mid-solve; by the time the gap is small, most
//! inactive features are frozen even without any λ-path context.
//!
//! Proof of the ball: `D` is 1-strongly concave and `α*` maximizes `D`
//! over the feasible set containing `α̂`, so
//! `D(α*) − D(α̂) ≥ ... ` — standard strong-concavity argument gives
//! `½‖α* − α̂‖² ≤ D(α*) − D(α̂) ≤ P(w) − D(α̂)` using weak duality.

use crate::data::FeatureMatrix;
use crate::svm::dual::DualPoint;

/// Per-feature gap-ball screening bounds at the current λ.
///
/// `alpha_hat` must be dual-feasible for `lambda` (as produced by
/// [`crate::svm::dual::duality_gap`]) and `gap = P − D(α̂) ≥ 0`.
/// Returns `max_θ |θᵀf̂_j|` bounds; feature `j` is provably inactive at
/// the optimum when the bound is < 1.
pub fn gap_ball_bounds<X: FeatureMatrix>(
    x: &X,
    y: &[f64],
    dual: &DualPoint,
    gap: f64,
) -> Vec<f64> {
    let lambda = dual.lambda;
    let radius = (2.0 * gap.max(0.0)).sqrt() / lambda;
    let ytheta: Vec<f64> = y
        .iter()
        .zip(&dual.alpha)
        .map(|(yi, ai)| yi * ai / lambda)
        .collect();
    (0..x.n_features())
        .map(|j| {
            let center = x.col_dot(j, &ytheta).abs();
            let norm = x.col_norm_sq(j).sqrt();
            center + radius * norm
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::data::FeatureMatrix;
    use crate::solver::api::{solve, SolveOptions, SolverKind};
    use crate::svm::dual::duality_gap;
    use crate::svm::problem::Problem;
    use crate::testkit::assert_dominates;

    /// The gap ball must contain the true dual optimum: bounds dominate
    /// |θ*ᵀf̂| for every feature, at every intermediate iterate quality.
    #[test]
    fn gap_ball_dominates_true_correlations() {
        let p = Problem::from_dataset(&SynthSpec::text(60, 150, 601).generate());
        let lambda = 0.4 * p.lambda_max();
        let exact =
            solve(SolverKind::Cd, &p.x, &p.y, lambda, None, &SolveOptions::precise())
                .unwrap();
        let theta_star = crate::svm::dual::theta_from_primal(
            &p.x, &p.y, &exact.w, exact.b, lambda,
        );
        let ytheta_star: Vec<f64> =
            p.y.iter().zip(&theta_star).map(|(a, b)| a * b).collect();
        // Crude iterates: w = 0 and a half-converged solve.
        for w in [
            vec![0.0; p.m()],
            solve(
                SolverKind::Cd,
                &p.x,
                &p.y,
                lambda,
                None,
                &SolveOptions { max_iter: 3, tol: 0.0, ..Default::default() },
            )
            .unwrap()
            .w,
        ] {
            let (rep, dual, _) = duality_gap(&p.x, &p.y, &w, lambda);
            let bounds = gap_ball_bounds(&p.x, &p.y, &dual, rep.gap);
            for j in 0..p.m() {
                let truth = p.x.col_dot(j, &ytheta_star).abs();
                assert_dominates(bounds[j], truth, 1e-7, &format!("feature {j}"));
            }
        }
    }

    /// End-to-end: dynamically screened coordinates are inactive in the
    /// certified optimum.
    #[test]
    fn gap_ball_screening_is_safe() {
        let p = Problem::from_dataset(&SynthSpec::dense(50, 60, 603).generate());
        let lambda = 0.3 * p.lambda_max();
        let exact =
            solve(SolverKind::Cd, &p.x, &p.y, lambda, None, &SolveOptions::precise())
                .unwrap();
        // Partially-converged state:
        let mid = solve(
            SolverKind::Cd,
            &p.x,
            &p.y,
            lambda,
            None,
            &SolveOptions { max_iter: 20, tol: 0.0, ..Default::default() },
        )
        .unwrap();
        let (rep, dual, _) = duality_gap(&p.x, &p.y, &mid.w, lambda);
        let bounds = gap_ball_bounds(&p.x, &p.y, &dual, rep.gap);
        let screened: Vec<usize> =
            (0..p.m()).filter(|&j| bounds[j] < 1.0 - 1e-6).collect();
        assert!(!screened.is_empty(), "gap {:.2e} should screen something", rep.gap);
        for j in screened {
            assert!(
                exact.w[j].abs() < 1e-7,
                "dynamically screened feature {j} is active (w = {})",
                exact.w[j]
            );
        }
    }

    /// Bounds tighten monotonically with the gap.
    #[test]
    fn bounds_shrink_as_gap_shrinks() {
        let p = Problem::from_dataset(&SynthSpec::text(40, 80, 605).generate());
        let lambda = 0.5 * p.lambda_max();
        let mut prev_sum = f64::INFINITY;
        for iters in [1usize, 10, 100] {
            let rep = solve(
                SolverKind::Cd,
                &p.x,
                &p.y,
                lambda,
                None,
                &SolveOptions { max_iter: iters, tol: 0.0, ..Default::default() },
            )
            .unwrap();
            let (g, dual, _) = duality_gap(&p.x, &p.y, &rep.w, lambda);
            let bounds = gap_ball_bounds(&p.x, &p.y, &dual, g.gap);
            let sum: f64 = bounds.iter().sum();
            assert!(sum <= prev_sum * (1.0 + 1e-6), "sum {sum} > prev {prev_sum}");
            prev_sum = sum;
        }
    }
}
