//! The screening-rule façade used by the path runner, the coordinator
//! and the benches.

use super::paper;
use super::precompute::{FeatureStats, SharedContext};
use super::variants;
use crate::data::cache::FeatureCache;
use crate::data::FeatureMatrix;
use crate::error::Result;

/// Keep margin: a feature is kept iff `bound ≥ 1 − KEEP_MARGIN`.
///
/// The bound is *tight*: for a feature active at λ₂, `|θ₂ᵀf̂| = 1` and the
/// max over K can equal exactly 1, so rounding (and the O(√gap) error in
/// a solver-produced θ₁) can push the computed bound a few ulps below 1.
/// The margin absorbs both; with the default solver tolerance (rel gap
/// ≤ 1e−6) no violation has ever been observed (T2 audits). Inactive
/// features' bounds are not clustered near 1, so the screening-power cost
/// is negligible.
pub const KEEP_MARGIN: f64 = 1e-6;

/// The keep threshold `1 − KEEP_MARGIN`.
pub const KEEP_THRESHOLD: f64 = 1.0 - KEEP_MARGIN;

/// Which screening rule to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleKind {
    /// The paper's full rule (half-space ∩ ball ∩ equality, 3 KKT cases).
    Paper,
    /// Ball ∩ equality only (Thm 6.7 unconditionally) — ablation.
    BallEq,
    /// Plain Cauchy–Schwarz sphere — weakest safe baseline.
    Sphere,
    /// Strong rule — *unsafe* heuristic baseline.
    Strong,
    /// Keep everything (no screening).
    None,
}

impl RuleKind {
    /// All safe rules (used by safety sweeps).
    pub const SAFE: [RuleKind; 3] = [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere];

    /// Parses `"paper" | "ball" | "sphere" | "strong" | "none"`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "paper" => Some(RuleKind::Paper),
            "ball" => Some(RuleKind::BallEq),
            "sphere" => Some(RuleKind::Sphere),
            "strong" => Some(RuleKind::Strong),
            "none" => Some(RuleKind::None),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::Paper => "paper",
            RuleKind::BallEq => "ball",
            RuleKind::Sphere => "sphere",
            RuleKind::Strong => "strong",
            RuleKind::None => "none",
        }
    }

    /// Whether the rule is guaranteed safe.
    pub fn is_safe(&self) -> bool {
        !matches!(self, RuleKind::Strong)
    }
}

/// Outcome of screening all m features for one λ₂.
#[derive(Debug, Clone)]
pub struct ScreenReport {
    /// The rule used.
    pub rule: RuleKind,
    /// λ₁ (source) and λ₂ (target).
    pub lambda1: f64,
    /// Target λ.
    pub lambda2: f64,
    /// Per-feature keep decision.
    pub keep: Vec<bool>,
    /// Per-feature bound value (`∞` where a rule keeps unconditionally).
    pub bounds: Vec<f64>,
    /// Seconds spent screening.
    pub seconds: f64,
}

impl ScreenReport {
    /// Seals a report from per-feature bounds: the keep mask is derived
    /// with the same [`KEEP_THRESHOLD`] comparison every sweep path
    /// (sequential, batched, block-parallel, sharded) shares, so two
    /// paths that produce bit-identical bounds produce identical
    /// kept sets by construction.
    pub fn from_bounds(
        rule: RuleKind,
        lambda1: f64,
        lambda2: f64,
        bounds: Vec<f64>,
        seconds: f64,
    ) -> Self {
        let keep = bounds.iter().map(|&b| b >= KEEP_THRESHOLD).collect();
        ScreenReport { rule, lambda1, lambda2, keep, bounds, seconds }
    }

    /// Number of screened-out (discarded) features.
    pub fn n_screened(&self) -> usize {
        self.keep.iter().filter(|k| !**k).count()
    }

    /// Fraction of features discarded (the paper's rejection ratio).
    pub fn rejection_ratio(&self) -> f64 {
        self.n_screened() as f64 / self.keep.len().max(1) as f64
    }

    /// Indices of kept features.
    pub fn kept_indices(&self) -> Vec<usize> {
        self.keep
            .iter()
            .enumerate()
            .filter(|(_, k)| **k)
            .map(|(j, _)| j)
            .collect()
    }
}

/// A screening rule bound to its kind: evaluates one feature.
pub trait ScreeningRule {
    /// The rule's kind tag.
    fn kind(&self) -> RuleKind;
    /// `true` to keep the feature (bound ≥ [`KEEP_THRESHOLD`]).
    fn keep(&self, ctx: &SharedContext, s: &FeatureStats) -> bool {
        self.score(ctx, s) >= KEEP_THRESHOLD
    }
    /// The bound/score (≥ 1 ⇔ keep).
    fn score(&self, ctx: &SharedContext, s: &FeatureStats) -> f64;
}

/// Unit struct implementing [`ScreeningRule`] per [`RuleKind`].
#[derive(Debug, Clone, Copy)]
pub struct Rule(pub RuleKind);

impl ScreeningRule for Rule {
    fn kind(&self) -> RuleKind {
        self.0
    }
    fn score(&self, ctx: &SharedContext, s: &FeatureStats) -> f64 {
        match self.0 {
            RuleKind::Paper => paper::bound(ctx, s),
            RuleKind::BallEq => variants::ball_eq_bound(ctx, s),
            RuleKind::Sphere => variants::sphere_bound(ctx, s),
            RuleKind::Strong => variants::strong_score(ctx, s),
            RuleKind::None => f64::INFINITY,
        }
    }
}

/// Screens all features of `x` for `lambda2`, given the solved dual point
/// `(lambda1, theta1)`. This is Algorithm 1 of the paper generalized over
/// rule variants — the single-threaded reference implementation (the
/// coordinator has a block-parallel version).
pub fn screen_all<X: FeatureMatrix>(
    rule: RuleKind,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2: f64,
) -> Result<ScreenReport> {
    screen_all_with(rule, x, y, theta1, lambda1, lambda2, None)
}

/// [`screen_all`] with an optional [`FeatureCache`]: the λ-independent
/// stats (`f̂ᵀy`, `f̂ᵀ1`, `‖f̂‖²`) are served from the cache, shrinking
/// the per-feature work to the single θ-dependent dot. Bit-identical to
/// the uncached path (asserted by the `cache` integration tests).
pub fn screen_all_with<X: FeatureMatrix>(
    rule: RuleKind,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2: f64,
    cache: Option<&FeatureCache>,
) -> Result<ScreenReport> {
    let t0 = std::time::Instant::now();
    let m = x.n_features();
    let mut bounds = vec![f64::INFINITY; m];
    if rule != RuleKind::None {
        let ctx = SharedContext::build(y, theta1, lambda1, lambda2)?;
        let r = Rule(rule);
        for (j, bound) in bounds.iter_mut().enumerate() {
            let s = match cache {
                Some(c) => FeatureStats::from_cache(x, c, j, &ctx.ytheta1),
                None => FeatureStats::compute(x, j, y, &ctx.ytheta1),
            };
            *bound = r.score(&ctx, &s);
        }
    }
    let report = ScreenReport::from_bounds(
        rule,
        lambda1,
        lambda2,
        bounds,
        t0.elapsed().as_secs_f64(),
    );
    record_screen_telemetry(&report, 1, "seq");
    Ok(report)
}

/// Reports a finished sweep into the global telemetry registry:
/// features screened/kept (by rule kind) plus the sweep-latency
/// histogram. `sweeps` is the number of O(nnz) data passes the report
/// amortizes (1 for [`screen_all`]; `1/k`-shared for [`screen_multi`],
/// which calls this once per target with `sweeps = 0` after the first).
/// `source` tags which sweep path produced the report (`"seq"` /
/// `"batch"` / `"par"` / `"shard"`) and flows into the provenance ledger
/// ([`crate::diag::ledger`]), which — when enabled — records one
/// per-feature verdict per report. The ledger only *reads* the sealed
/// report, so screening results are identical either way.
pub(crate) fn record_screen_telemetry(
    report: &ScreenReport,
    sweeps: u64,
    source: &'static str,
) {
    crate::diag::ledger::global().record_report(report, source);
    use crate::telemetry::BucketSpec;
    let tele = crate::telemetry::global();
    let name = report.rule.name();
    let kept = report.keep.len() - report.n_screened();
    tele.counter(&format!("screening.{name}.sweeps")).add(sweeps);
    tele.counter(&format!("screening.{name}.features_screened"))
        .add(report.n_screened() as u64);
    tele.counter(&format!("screening.{name}.features_kept")).add(kept as u64);
    tele.histogram("screening.sweep_seconds").record(report.seconds);
    // Screening-efficacy distributions: how much each rule rejects and
    // how big the surviving problem is, across every λ₂ screened.
    tele.histogram(&format!("screening.{name}.rejection"))
        .record(report.rejection_ratio());
    tele.histogram_with(&format!("screening.{name}.kept_size"), BucketSpec::COUNTS)
        .record(kept as f64);
    // Per-λ view: the rejection ratio varies strongly along the path, so
    // bucket it by the λ₂/λ₁ decile (d9 ≈ just below λ_max, d0 ≈ deep
    // path). Gauges are last-value-wins; with the sequential runner each
    // decile holds the most recent ratio observed in that λ range.
    let frac = report.lambda2 / report.lambda1;
    if frac.is_finite() && (0.0..=1.0).contains(&frac) {
        let decile = ((frac * 10.0).floor() as usize).min(9);
        tele.gauge(&format!("screening.{name}.rejection.d{decile}"))
            .set(report.rejection_ratio());
    }
    crate::tele_debug!(
        "screening",
        "rule {name} l2/l1 {:.4}: screened {}/{} ({:.1}%) in {}",
        report.lambda2 / report.lambda1,
        report.n_screened(),
        report.keep.len(),
        100.0 * report.rejection_ratio(),
        crate::report::timer::fmt_duration(report.seconds)
    );
}

/// Screens the same features for **several** target λ₂ in one pass over
/// the data — the stats panel `(f̂ᵀy, f̂ᵀ1, f̂ᵀθ₁, ‖f̂‖²)` is independent
/// of λ₂, so k targets cost one O(nnz) sweep plus k O(1) bound
/// evaluations per feature. This is the server batcher's amortization
/// (§6.4's precompute-sharing taken across requests).
pub fn screen_multi<X: FeatureMatrix>(
    rule: RuleKind,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2s: &[f64],
) -> Result<Vec<ScreenReport>> {
    screen_multi_with(rule, x, y, theta1, lambda1, lambda2s, None)
}

/// [`screen_multi`] with an optional [`FeatureCache`] (same semantics as
/// [`screen_all_with`]): the batch's shared data pass shrinks to the
/// θ-dot alone.
pub fn screen_multi_with<X: FeatureMatrix>(
    rule: RuleKind,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2s: &[f64],
    cache: Option<&FeatureCache>,
) -> Result<Vec<ScreenReport>> {
    let t0 = std::time::Instant::now();
    let m = x.n_features();
    let k = lambda2s.len();
    if rule == RuleKind::None || k == 0 {
        return lambda2s
            .iter()
            .map(|&l2| screen_all_with(rule, x, y, theta1, lambda1, l2, cache))
            .collect();
    }
    let ctxs: Vec<SharedContext> = lambda2s
        .iter()
        .map(|&l2| SharedContext::build(y, theta1, lambda1, l2))
        .collect::<Result<_>>()?;
    let r = Rule(rule);
    let mut bounds = vec![vec![f64::INFINITY; m]; k];
    for j in 0..m {
        // One data pass, shared by all targets (ytheta1 identical per ctx).
        let s = match cache {
            Some(c) => FeatureStats::from_cache(x, c, j, &ctxs[0].ytheta1),
            None => FeatureStats::compute(x, j, y, &ctxs[0].ytheta1),
        };
        for (t, ctx) in ctxs.iter().enumerate() {
            bounds[t][j] = r.score(ctx, &s);
        }
    }
    let seconds = t0.elapsed().as_secs_f64() / k as f64;
    let reports: Vec<ScreenReport> = lambda2s
        .iter()
        .zip(bounds)
        .map(|(&l2, bounds)| {
            ScreenReport::from_bounds(rule, lambda1, l2, bounds, seconds)
        })
        .collect();
    for (i, rep) in reports.iter().enumerate() {
        // The whole batch shares one data sweep; count it once.
        record_screen_telemetry(rep, if i == 0 { 1 } else { 0 }, "batch");
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::solver::api::{solve, SolveOptions, SolverKind};
    use crate::svm::problem::Problem;

    #[test]
    fn multi_matches_single() {
        let p = Problem::from_dataset(&SynthSpec::text(40, 100, 105).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        let l2s = [0.9 * l1, 0.6 * l1, 0.3 * l1];
        let multi =
            screen_multi(RuleKind::Paper, &p.x, &p.y, &theta1, l1, &l2s).unwrap();
        for (rep, &l2) in multi.iter().zip(&l2s) {
            let single =
                screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, l1, l2).unwrap();
            assert_eq!(rep.keep, single.keep, "lambda2={l2}");
            assert_eq!(rep.lambda2, l2);
        }
    }

    #[test]
    fn kinds_parse() {
        for k in [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong, RuleKind::None]
        {
            assert_eq!(RuleKind::parse(k.name()), Some(k));
        }
        assert_eq!(RuleKind::parse("bogus"), None);
        assert!(RuleKind::Paper.is_safe());
        assert!(!RuleKind::Strong.is_safe());
    }

    #[test]
    fn none_rule_keeps_everything() {
        let p = Problem::from_dataset(&SynthSpec::dense(20, 10, 95).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let rep = screen_all(
            RuleKind::None,
            &p.x,
            &p.y,
            &theta1,
            p.lambda_max(),
            0.5 * p.lambda_max(),
        )
        .unwrap();
        assert_eq!(rep.n_screened(), 0);
        assert_eq!(rep.rejection_ratio(), 0.0);
        assert_eq!(rep.kept_indices().len(), 10);
    }

    /// End-to-end SAFETY: for every safe rule and several λ₂, the
    /// screened-out features must be inactive in the true optimum.
    #[test]
    fn safety_end_to_end() {
        for spec in [
            SynthSpec::dense(50, 40, 97),
            SynthSpec::text(60, 120, 98),
            SynthSpec::corr(40, 30, 99),
        ] {
            let p = Problem::from_dataset(&spec.generate());
            let theta1 = p.theta_at_lambda_max().theta();
            for frac in [0.95, 0.8, 0.5, 0.2] {
                let lambda2 = frac * p.lambda_max();
                let exact = solve(
                    SolverKind::Cd,
                    &p.x,
                    &p.y,
                    lambda2,
                    None,
                    &SolveOptions::precise(),
                )
                .unwrap();
                assert!(exact.converged);
                for rule in RuleKind::SAFE {
                    let rep = screen_all(
                        rule,
                        &p.x,
                        &p.y,
                        &theta1,
                        p.lambda_max(),
                        lambda2,
                    )
                    .unwrap();
                    for j in 0..p.m() {
                        if !rep.keep[j] {
                            assert!(
                                exact.w[j].abs() < 1e-7,
                                "{} rule {} frac {frac}: screened feature {j} \
                                 is active (w={})",
                                p.name,
                                rule.name(),
                                exact.w[j]
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn paper_screens_at_least_as_much_as_relaxations() {
        let p = Problem::from_dataset(&SynthSpec::text(60, 200, 101).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let l2 = 0.7 * p.lambda_max();
        let paper =
            screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, p.lambda_max(), l2).unwrap();
        let ball =
            screen_all(RuleKind::BallEq, &p.x, &p.y, &theta1, p.lambda_max(), l2).unwrap();
        let sphere =
            screen_all(RuleKind::Sphere, &p.x, &p.y, &theta1, p.lambda_max(), l2).unwrap();
        assert!(paper.n_screened() >= ball.n_screened());
        assert!(ball.n_screened() >= sphere.n_screened());
        // and anything ball keeps, paper decision is consistent per-feature
        for j in 0..p.m() {
            if !ball.keep[j] {
                assert!(!paper.keep[j], "ball screened {j} but paper kept it");
            }
        }
    }

    #[test]
    fn screening_power_nontrivial_near_lambda_max() {
        let p = Problem::from_dataset(&SynthSpec::text(80, 300, 103).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let rep = screen_all(
            RuleKind::Paper,
            &p.x,
            &p.y,
            &theta1,
            p.lambda_max(),
            0.9 * p.lambda_max(),
        )
        .unwrap();
        assert!(
            rep.rejection_ratio() > 0.5,
            "expected strong screening near lambda_max, got {}",
            rep.rejection_ratio()
        );
    }
}
