//! Slow numerical reference for the screening bound (tests only).
//!
//! Solves Eq. (46) directly:
//!
//! ```text
//! neg_min(f̂) = max_{r}  −f̂ᵀr − cᵀf̂... more precisely
//!              −min rᵀf̂ − cᵀf̂  over
//!              ‖r‖ ≤ ‖b‖,  aᵀ(b + r) ≤ 0,  (c + r)ᵀy = 0
//! ```
//!
//! by projected gradient ascent on the linear objective with a Dykstra
//! projection onto the (ball ∩ half-space ∩ hyperplane) intersection.
//! Because the returned value is evaluated at a *feasible* point, it is
//! a certified lower bound on the true maximum: the closed forms of
//! [`super::paper`] must dominate it, and equal it at the optimum.

use crate::linalg::{dot, nrm2, nrm2_sq};

struct Sets {
    radius: f64,
    /// unit half-space normal (empty ⇒ no half-space constraint)
    a: Vec<f64>,
    /// half-space offset: aᵀ r ≤ a_off
    a_off: f64,
    y: Vec<f64>,
    ysq: f64,
    /// hyperplane offset: yᵀ r = y_off
    y_off: f64,
}

impl Sets {
    fn proj_ball(&self, r: &mut [f64]) {
        let n = nrm2(r);
        if n > self.radius && n > 0.0 {
            let s = self.radius / n;
            for v in r.iter_mut() {
                *v *= s;
            }
        }
    }
    fn proj_half(&self, r: &mut [f64]) {
        if self.a.is_empty() {
            return;
        }
        let v = dot(&self.a, r) - self.a_off;
        if v > 0.0 {
            for (ri, ai) in r.iter_mut().zip(&self.a) {
                *ri -= v * ai;
            }
        }
    }
    fn proj_plane(&self, r: &mut [f64]) {
        if self.ysq == 0.0 {
            return;
        }
        let v = (dot(&self.y, r) - self.y_off) / self.ysq;
        for (ri, yi) in r.iter_mut().zip(&self.y) {
            *ri -= v * yi;
        }
    }

    /// Dykstra's algorithm onto the three-set intersection.
    fn project(&self, r: &mut Vec<f64>, iters: usize) {
        let n = r.len();
        let mut p = vec![0.0; n];
        let mut q = vec![0.0; n];
        let mut s = vec![0.0; n];
        for _ in 0..iters {
            // ball
            for i in 0..n {
                r[i] += p[i];
            }
            let before: Vec<f64> = r.clone();
            self.proj_ball(r);
            for i in 0..n {
                p[i] = before[i] - r[i];
            }
            // half-space
            for i in 0..n {
                r[i] += q[i];
            }
            let before: Vec<f64> = r.clone();
            self.proj_half(r);
            for i in 0..n {
                q[i] = before[i] - r[i];
            }
            // hyperplane (affine: no correction memory needed, but keep
            // the symmetric structure)
            for i in 0..n {
                r[i] += s[i];
            }
            let before: Vec<f64> = r.clone();
            self.proj_plane(r);
            for i in 0..n {
                s[i] = before[i] - r[i];
            }
        }
        // final safety: make r strictly feasible
        self.proj_plane(r);
        self.proj_half(r);
        self.proj_ball(r);
    }

    fn feasible(&self, r: &[f64], tol: f64) -> bool {
        nrm2(r) <= self.radius * (1.0 + tol) + tol
            && (self.a.is_empty() || dot(&self.a, r) <= self.a_off + tol)
            && (dot(&self.y, r) - self.y_off).abs() <= tol * (1.0 + self.y_off.abs())
    }
}

/// Numerically computes `neg_min(f̂) = −min_{θ∈K} θᵀf̂` for the paper's
/// set K built from `(y, θ₁, λ₁, λ₂)`. Returns a value achieved at a
/// feasible point (certified lower bound on the exact maximum).
pub fn qcqp_neg_min(y: &[f64], theta1: &[f64], l1: f64, l2: f64, fhat: &[f64]) -> f64 {
    let n = y.len();
    let inv1 = 1.0 / l1;
    let inv2 = 1.0 / l2;
    let b: Vec<f64> = theta1.iter().map(|t| 0.5 * (inv2 - t)).collect();
    let c: Vec<f64> = theta1.iter().map(|t| 0.5 * (inv2 + t)).collect();
    // The correct half-space side is aᵀ(b + r) ≥ 0 (it is the Eq. 31
    // variational inequality with b + r = θ₂ − θ₁) — expressed here with
    // the flipped normal â = −a so the Sets type keeps one convention
    // (âᵀ r ≤ âᵀ·offset).
    let a_raw: Vec<f64> = theta1.iter().map(|t| t - inv1).collect();
    let na = nrm2(&a_raw);
    let a: Vec<f64> = if na > 1e-12 {
        a_raw.iter().map(|v| -v / na).collect()
    } else {
        Vec::new()
    };
    let a_off = if a.is_empty() { 0.0 } else { -dot(&a, &b) };
    let sets = Sets {
        radius: nrm2(&b),
        a,
        a_off,
        y: y.to_vec(),
        ysq: nrm2_sq(y),
        y_off: -dot(&c, y),
    };

    // Maximize g(r) = −f̂ᵀ r via projected gradient ascent from several
    // starts; track the best feasible value.
    let fn_norm = nrm2(fhat).max(1e-12);
    let mut best = f64::NEG_INFINITY;
    let starts: Vec<Vec<f64>> = vec![
        vec![0.0; n],
        fhat.iter().map(|v| -sets.radius * v / fn_norm).collect(),
        b.iter().map(|v| -*v).collect(),
    ];
    for start in starts {
        let mut r = start;
        sets.project(&mut r, 200);
        let step0 = sets.radius.max(1e-9) / fn_norm;
        for k in 0..3000 {
            let step = step0 / (1.0 + 0.01 * k as f64);
            for i in 0..n {
                r[i] -= step * fhat[i];
            }
            sets.project(&mut r, 60);
            if k % 50 == 0 && sets.feasible(&r, 1e-7) {
                best = best.max(-dot(&r, fhat));
            }
        }
        sets.project(&mut r, 400);
        if sets.feasible(&r, 1e-6) {
            best = best.max(-dot(&r, fhat));
        }
    }
    // neg_min(θᵀf̂) = max(−rᵀf̂) − cᵀf̂
    best - dot(&c, fhat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn ball_only_analytic_case() {
        // With theta1 = inv1 (no half-space) and y "absorbed": pick y
        // orthogonal setup where the answer is the sphere bound on the
        // y-complement. Simple sanity: neg_min >= -c'fhat (r = 0 feasible
        // when c'y = 0).
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let theta1 = vec![0.5; 4]; // theta1'y = 0; a degenerate
        let fhat = vec![1.0, 0.2, -0.3, 0.4];
        let v = qcqp_neg_min(&y, &theta1, 2.0, 1.0);
        // compare against closed-form ball∩equality (Thm 6.7):
        let ctx =
            crate::screening::SharedContext::build(&y, &theta1, 2.0, 1.0).unwrap();
        let s = crate::screening::FeatureStats {
            dy: crate::linalg::dot(&fhat, &y),
            d1: crate::linalg::sum(&fhat),
            dt: crate::linalg::dot(&fhat, &theta1),
            q: crate::linalg::nrm2_sq(&fhat),
        };
        let closed = crate::screening::paper::neg_min(&ctx, &s);
        assert_close(v, closed, 5e-3, "qcqp vs closed (degenerate a)");
    }

    fn qcqp_neg_min(y: &[f64], theta1: &[f64], l1: f64, l2: f64) -> f64 {
        super::qcqp_neg_min(y, theta1, l1, l2, &[1.0, 0.2, -0.3, 0.4])
    }

    #[test]
    fn projection_components() {
        let sets = Sets {
            radius: 1.0,
            a: vec![1.0, 0.0],
            a_off: 0.0,
            y: vec![0.0, 1.0],
            ysq: 1.0,
            y_off: 0.5,
        };
        let mut r = vec![3.0, 4.0];
        sets.proj_ball(&mut r);
        assert_close(nrm2(&r), 1.0, 1e-12, "ball radius");
        let mut r = vec![0.7, 0.0];
        sets.proj_half(&mut r);
        assert!(dot(&sets.a, &r) <= 1e-12);
        let mut r = vec![0.3, 2.0];
        sets.proj_plane(&mut r);
        assert_close(r[1], 0.5, 1e-12, "plane coordinate");
        // dykstra lands in the intersection
        let mut r = vec![5.0, -5.0];
        sets.project(&mut r, 300);
        assert!(sets.feasible(&r, 1e-6), "{r:?}");
    }
}
