//! Typed run configuration with a TOML-subset file format and CLI
//! overrides (no serde/toml crates in the vendored set).
//!
//! The accepted file syntax: `key = value` lines, `#` comments, bare
//! strings/numbers/bools. Keys mirror the CLI flags (`--steps 30` ⇔
//! `steps = 30`).

use crate::error::{Error, Result};
use crate::path::runner::PathConfig;
use crate::screening::rule::RuleKind;
use crate::solver::api::{SolveOptions, SolverKind};
use std::collections::BTreeMap;

/// Flat key/value configuration source.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    /// Parses the TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let v = v.trim().trim_matches('"');
            values.insert(k.trim().to_string(), v.to_string());
        }
        Ok(RawConfig { values })
    }

    /// Loads from a file path.
    pub fn load(path: &str) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Overrides/sets a key.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    /// String accessor.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// f64 accessor with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("{key}: bad number {v:?}"))),
        }
    }

    /// usize accessor with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("{key}: bad integer {v:?}"))),
        }
    }

    /// bool accessor with default.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => Err(Error::config(format!("{key}: bad bool {v:?}"))),
        }
    }
}

/// The resolved run configuration shared by CLI subcommands.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Dataset source: `synth:<kind>:<n>:<m>:<seed>` or a libsvm path.
    pub data: String,
    /// Screening rule.
    pub rule: RuleKind,
    /// Solver.
    pub solver: SolverKind,
    /// Path grid size.
    pub steps: usize,
    /// Path grid lower endpoint as a fraction of λ_max.
    pub min_frac: f64,
    /// Solver tolerance (relative duality gap).
    pub tol: f64,
    /// Worker threads for parallel screening / the server.
    pub workers: usize,
    /// Feature shards for the screening server (`--shards`, or the
    /// `PALLAS_SHARDS` env var as the default). `<= 1` disables
    /// sharding.
    pub shards: usize,
    /// Execution engine: `native` or `pjrt`.
    pub engine: String,
    /// Artifact directory for the PJRT engine.
    pub artifact_dir: String,
    /// Server bind address.
    pub addr: String,
    /// Chrome-trace output path (`--trace-out`), if requested.
    pub trace_out: Option<String>,
    /// Safety-audit mode: re-check screened features at convergence.
    pub audit: bool,
    /// Provenance-ledger mode: record per-feature screening verdicts
    /// into [`crate::diag::ledger`] (implied by the `explain` command).
    pub ledger: bool,
    /// Near-miss threshold: a feature whose screening margin lands
    /// within this epsilon of the keep/reject boundary is flagged.
    pub near_miss_eps: f64,
}

/// Default shard count: `PALLAS_SHARDS` when set and parseable,
/// otherwise 1 (unsharded).
fn default_shards() -> usize {
    std::env::var("PALLAS_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

impl RunConfig {
    /// Resolves from a raw key/value source.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let rule_s = raw.get("rule").unwrap_or("paper");
        let rule = RuleKind::parse(rule_s)
            .ok_or_else(|| Error::config(format!("unknown rule {rule_s:?}")))?;
        let solver_s = raw.get("solver").unwrap_or("cd");
        let solver = SolverKind::parse(solver_s)
            .ok_or_else(|| Error::config(format!("unknown solver {solver_s:?}")))?;
        let engine = raw.get("engine").unwrap_or("native").to_string();
        if engine != "native" && engine != "pjrt" {
            return Err(Error::config(format!("unknown engine {engine:?}")));
        }
        Ok(RunConfig {
            data: raw.get("data").unwrap_or("synth:text:2000:20000:42").to_string(),
            rule,
            solver,
            steps: raw.get_usize("steps", 30)?,
            min_frac: raw.get_f64("min-frac", 0.05)?,
            tol: raw.get_f64("tol", 1e-6)?,
            workers: raw
                .get_usize("workers", crate::coordinator::pool::default_workers())?,
            shards: raw.get_usize("shards", default_shards())?,
            engine,
            artifact_dir: raw.get("artifacts").unwrap_or("artifacts").to_string(),
            addr: raw.get("addr").unwrap_or("127.0.0.1:7878").to_string(),
            trace_out: raw.get("trace-out").map(str::to_string),
            audit: raw.get_bool("audit", false)?,
            ledger: raw.get_bool("ledger", false)?,
            near_miss_eps: raw
                .get_f64("near-miss-eps", crate::diag::ledger::DEFAULT_NEAR_MISS_EPS)?,
        })
    }

    /// The solver options implied by this config.
    pub fn solve_options(&self) -> SolveOptions {
        SolveOptions { tol: self.tol, ..Default::default() }
    }

    /// The path-runner config implied by this config.
    pub fn path_config(&self) -> PathConfig {
        PathConfig {
            rule: self.rule,
            solver: self.solver,
            solve: self.solve_options(),
            audit: self.audit,
            workers: self.workers,
            near_miss_eps: self.near_miss_eps,
            ..Default::default()
        }
    }

    /// Materializes the dataset described by `data`.
    pub fn load_dataset(&self) -> Result<crate::data::dataset::Dataset> {
        if let Some(spec) = self.data.strip_prefix("synth:") {
            let parts: Vec<&str> = spec.split(':').collect();
            if parts.len() != 4 {
                return Err(Error::config(
                    "synth spec must be synth:<kind>:<n>:<m>:<seed>",
                ));
            }
            let kind = crate::data::synth::SynthKind::parse(parts[0])
                .ok_or_else(|| Error::config(format!("unknown synth kind {:?}", parts[0])))?;
            let n: usize = parts[1].parse().map_err(|_| Error::config("bad synth n"))?;
            let m: usize = parts[2].parse().map_err(|_| Error::config("bad synth m"))?;
            let seed: u64 =
                parts[3].parse().map_err(|_| Error::config("bad synth seed"))?;
            let spec = match kind {
                crate::data::synth::SynthKind::Dense => {
                    crate::data::synth::SynthSpec::dense(n, m, seed)
                }
                crate::data::synth::SynthKind::Text => {
                    crate::data::synth::SynthSpec::text(n, m, seed)
                }
                crate::data::synth::SynthKind::Corr => {
                    crate::data::synth::SynthSpec::corr(n, m, seed)
                }
            };
            Ok(spec.generate())
        } else {
            crate::data::libsvm::load(&self.data)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_file_syntax() {
        let raw = RawConfig::parse(
            "# comment\nsteps = 12\nrule = ball\ndata = \"synth:dense:10:5:1\"\ntol=1e-8\n",
        )
        .unwrap();
        assert_eq!(raw.get_usize("steps", 0).unwrap(), 12);
        assert_eq!(raw.get("rule"), Some("ball"));
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.rule, RuleKind::BallEq);
        assert_eq!(cfg.steps, 12);
        assert_eq!(cfg.tol, 1e-8);
        let ds = cfg.load_dataset().unwrap();
        assert_eq!(ds.n(), 10);
        assert_eq!(ds.m(), 5);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RawConfig::parse("novalue\n").is_err());
        let mut raw = RawConfig::default();
        raw.set("rule", "bogus");
        assert!(RunConfig::from_raw(&raw).is_err());
        let mut raw = RawConfig::default();
        raw.set("engine", "cuda");
        assert!(RunConfig::from_raw(&raw).is_err());
        let mut raw = RawConfig::default();
        raw.set("steps", "abc");
        assert!(RunConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn defaults_resolve() {
        let cfg = RunConfig::from_raw(&RawConfig::default()).unwrap();
        assert_eq!(cfg.rule, RuleKind::Paper);
        assert_eq!(cfg.solver, SolverKind::Cd);
        assert_eq!(cfg.engine, "native");
        assert!(cfg.workers >= 1);
        assert_eq!(cfg.trace_out, None);
        assert!(!cfg.audit);
        assert!(!cfg.path_config().audit);
        assert!(!cfg.ledger);
        assert_eq!(cfg.near_miss_eps, crate::diag::ledger::DEFAULT_NEAR_MISS_EPS);
    }

    #[test]
    fn ledger_flags_resolve() {
        let mut raw = RawConfig::default();
        raw.set("ledger", "true");
        raw.set("near-miss-eps", "1e-4");
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert!(cfg.ledger);
        assert_eq!(cfg.near_miss_eps, 1e-4);
        assert_eq!(cfg.path_config().near_miss_eps, 1e-4);
    }

    #[test]
    fn trace_and_audit_flags_resolve() {
        let mut raw = RawConfig::default();
        raw.set("trace-out", "out/trace.json");
        raw.set("audit", "true");
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("out/trace.json"));
        assert!(cfg.audit);
        assert!(cfg.path_config().audit);
    }

    #[test]
    fn shards_resolve() {
        // File/flag value wins; the env-var default applies otherwise.
        let mut raw = RawConfig::default();
        raw.set("shards", "4");
        assert_eq!(RunConfig::from_raw(&raw).unwrap().shards, 4);
        // Default path: PALLAS_SHARDS when exported, else 1. Tests may
        // run under either, so only pin it when the env var is absent.
        if std::env::var("PALLAS_SHARDS").is_err() {
            assert_eq!(RunConfig::from_raw(&RawConfig::default()).unwrap().shards, 1);
        }
        let mut raw = RawConfig::default();
        raw.set("shards", "abc");
        assert!(RunConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn bool_accessor() {
        let raw = RawConfig::parse("a = true\nb = 0\n").unwrap();
        assert!(raw.get_bool("a", false).unwrap());
        assert!(!raw.get_bool("b", true).unwrap());
        assert!(raw.get_bool("c", true).unwrap());
    }

    #[test]
    fn bad_synth_specs() {
        for data in ["synth:text:10", "synth:nope:1:2:3", "synth:text:a:2:3"] {
            let mut raw = RawConfig::default();
            raw.set("data", data);
            let cfg = RunConfig::from_raw(&raw).unwrap();
            assert!(cfg.load_dataset().is_err(), "{data}");
        }
    }
}
