//! svmscreen — the launcher binary.
//!
//! See [`svmscreen::cli::USAGE`] for the command reference. Every
//! subcommand resolves its configuration from an optional `--config`
//! file plus CLI flags, builds the dataset, and drives the library.

use svmscreen::cli::{parse_args, USAGE};
use svmscreen::config::{RawConfig, RunConfig};
use svmscreen::coordinator::server::{ScreeningServer, ServerConfig};
use svmscreen::error::Result;
use svmscreen::prelude::*;
use svmscreen::report::table::fnum;

fn main() {
    // Arm the telemetry sinks (PALLAS_LOG / PALLAS_LOG_JSON) before any
    // subsystem emits.
    svmscreen::telemetry::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            std::process::exit(1);
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = parse_args(args)?;
    if cli.command == "help" {
        println!("{USAGE}");
        return Ok(());
    }
    // Merge config file (if any) under CLI flags.
    let mut raw = match cli.flags.get("config") {
        Some(path) => RawConfig::load(path)?,
        None => RawConfig::default(),
    };
    // CLI flags override the file: re-apply them on top.
    for key in [
        "data", "rule", "solver", "steps", "min-frac", "tol", "workers", "engine",
        "artifacts", "addr", "lambda-frac", "lambda2-frac", "out", "csv",
        "trace-out", "audit", "ledger", "near-miss-eps", "feature", "top", "export",
        "shards",
    ] {
        if let Some(v) = cli.flags.get(key) {
            raw.set(key, v);
        }
    }
    let cfg = RunConfig::from_raw(&raw)?;

    let result = match cli.command.as_str() {
        "info" => cmd_info(&cfg),
        "generate" => cmd_generate(&cfg, raw.get("out")),
        "solve" => cmd_solve(&cfg, raw.get_f64("lambda-frac", 0.5)?),
        "screen" => cmd_screen(&cfg, raw.get_f64("lambda2-frac", 0.5)?),
        "path" => cmd_path(&cfg, raw.get("csv")),
        "explain" => cmd_explain(&cfg, &raw),
        "serve" => cmd_serve(&cfg),
        other => Err(svmscreen::error::Error::config(format!(
            "unknown command {other:?}"
        ))),
    };
    // Export the recorded timeline after the work (even a failed run's
    // partial trace is useful for diagnosis; a write failure must not
    // mask the run's own result).
    if let Some(path) = &cfg.trace_out {
        match svmscreen::telemetry::trace::write_chrome_file(path) {
            Ok(n) => println!("wrote {path} ({n} trace records; load in Perfetto)"),
            Err(e) => eprintln!("trace: cannot write {path}: {e}"),
        }
    }
    result
}

fn load_problem(cfg: &RunConfig) -> Result<Problem> {
    let ds = cfg.load_dataset()?;
    println!("{}", ds.describe());
    Ok(Problem::from_dataset(&ds))
}

fn cmd_info(cfg: &RunConfig) -> Result<()> {
    let p = load_problem(cfg)?;
    println!("lambda_max = {}", fnum(p.lambda_max()));
    println!("b*         = {}", fnum(p.b_star()));
    let ff = &p.lambda_max_stats().first_features;
    println!("first feature(s) to activate: {ff:?}");
    Ok(())
}

fn cmd_generate(cfg: &RunConfig, out: Option<&str>) -> Result<()> {
    let ds = cfg.load_dataset()?;
    let out = out.ok_or_else(|| svmscreen::error::Error::config("generate needs --out"))?;
    let file = std::fs::File::create(out)?;
    svmscreen::data::libsvm::save(&ds, std::io::BufWriter::new(file))?;
    println!("wrote {} ({} samples, {} features)", out, ds.n(), ds.m());
    Ok(())
}

fn cmd_solve(cfg: &RunConfig, lambda_frac: f64) -> Result<()> {
    let p = load_problem(cfg)?;
    let lambda = lambda_frac * p.lambda_max();
    let rep = svmscreen::solver::api::solve(
        cfg.solver,
        &p.x,
        &p.y,
        lambda,
        None,
        &cfg.solve_options(),
    )?;
    println!(
        "lambda = {} ({}·lambda_max)  solver={}",
        fnum(lambda),
        fnum(lambda_frac),
        cfg.solver.name()
    );
    println!(
        "nnz = {}  iterations = {}  rel_gap = {:.2e}  converged = {}  {:.3}s",
        rep.nnz(),
        rep.iterations,
        rep.gap.rel_gap,
        rep.converged,
        rep.seconds
    );
    Ok(())
}

fn cmd_screen(cfg: &RunConfig, lambda2_frac: f64) -> Result<()> {
    let p = load_problem(cfg)?;
    let theta1 = p.theta_at_lambda_max().theta();
    let l1 = p.lambda_max();
    let l2 = lambda2_frac * l1;
    let rep = if cfg.engine == "pjrt" {
        let engine = svmscreen::runtime::PjrtEngine::load(&cfg.artifact_dir)?;
        svmscreen::runtime::screen_all_pjrt(
            &engine,
            &p.x,
            &p.y,
            &theta1,
            l1,
            l2,
            &svmscreen::runtime::PjrtScreenOptions::default(),
        )?
    } else {
        svmscreen::coordinator::screen_all_parallel(
            cfg.rule,
            &p.x,
            &p.y,
            &theta1,
            l1,
            l2,
            cfg.workers,
        )?
    };
    println!(
        "rule={} engine={} lambda2 = {}·lambda_max",
        cfg.rule.name(),
        cfg.engine,
        fnum(lambda2_frac)
    );
    println!(
        "screened {} / {} features ({:.1}% rejection) in {:.4}s",
        rep.n_screened(),
        p.m(),
        100.0 * rep.rejection_ratio(),
        rep.seconds
    );
    Ok(())
}

fn cmd_path(cfg: &RunConfig, csv: Option<&str>) -> Result<()> {
    if cfg.ledger {
        let ledger = svmscreen::diag::ledger::global();
        ledger.set_enabled(true);
        ledger.set_near_miss_eps(cfg.near_miss_eps);
    }
    let p = load_problem(cfg)?;
    let grid = svmscreen::path::grid::geometric(p.lambda_max(), cfg.min_frac, cfg.steps)?;
    let report = run_path(&p, &grid, &cfg.path_config())?;
    println!("{}", report.summary_table());
    let t = report.totals();
    println!(
        "totals: screen {:.3}s solve {:.3}s mean-rejection {:.1}%",
        t.screen_seconds,
        t.solve_seconds,
        100.0 * t.mean_rejection
    );
    if cfg.audit {
        let audit_total: usize = report
            .steps
            .iter()
            .filter_map(|s| s.audit_violations)
            .sum();
        println!(
            "safety audit: {} KKT violation(s) across {} step(s)",
            audit_total,
            report.steps.len()
        );
    }
    if let Some(path) = csv {
        let rows: Vec<Vec<String>> =
            report.steps.iter().map(|s| s.row().to_vec()).collect();
        svmscreen::report::csv::write_file(
            path,
            &svmscreen::path::stats::PathStep::header(),
            &rows,
        )?;
        println!("wrote {path}");
    }
    if cfg.ledger {
        print_ledger_summary(&svmscreen::diag::ledger::global().summary());
    }
    Ok(())
}

fn print_ledger_summary(s: &svmscreen::diag::LedgerSummary) {
    println!(
        "ledger: {} verdict(s) recorded, {} buffered, {} evicted, {} near-miss(es) (eps {:.1e})",
        s.recorded, s.buffered, s.dropped, s.near_misses, s.near_miss_eps
    );
    for (rule, kept, rejected, near) in &s.by_rule {
        println!("  rule {rule:<7} kept {kept:>7}  rejected {rejected:>7}  near-miss {near:>5}");
    }
}

fn print_verdict(v: &svmscreen::diag::Verdict) {
    println!(
        "  sweep {:>3}  feature {:>6}  {}/{}  lambda2 {:.4e}  bound {:.6}  margin {:+.3e}  {}{}",
        v.sweep,
        v.feature,
        v.rule,
        v.source,
        v.lambda2,
        v.bound,
        v.margin,
        if v.kept { "kept" } else { "rejected" },
        if v.near_miss { "  NEAR MISS" } else { "" },
    );
}

/// `explain`: a path run with the provenance ledger armed, followed by
/// the decision story — per-rule near-miss breakdown, the closest
/// calls, an optional single-feature history, and any solver-anomaly
/// convergence summaries. `--export FILE` dumps every verdict.
fn cmd_explain(cfg: &RunConfig, raw: &RawConfig) -> Result<()> {
    let ledger = svmscreen::diag::ledger::global();
    ledger.set_enabled(true);
    ledger.set_near_miss_eps(cfg.near_miss_eps);
    ledger.clear();
    svmscreen::diag::convergence::clear_log();

    let p = load_problem(cfg)?;
    let grid = svmscreen::path::grid::geometric(p.lambda_max(), cfg.min_frac, cfg.steps)?;
    let report = run_path(&p, &grid, &cfg.path_config())?;
    println!("{}", report.summary_table());
    print_ledger_summary(&ledger.summary());

    if raw.get("feature").is_some() {
        let j = raw.get_usize("feature", 0)?;
        let history = ledger.feature_history(j);
        println!("\nfeature {j}: {} recorded verdict(s)", history.len());
        for v in &history {
            print_verdict(v);
        }
    }

    let top_n = raw.get_usize("top", 10)?;
    let top = ledger.top_near_misses(top_n);
    if top.is_empty() {
        println!("\nno near-misses within eps {:.1e}", ledger.near_miss_eps());
    } else {
        println!(
            "\ntop {} near-miss verdict(s), closest call first (eps {:.1e}):",
            top.len(),
            ledger.near_miss_eps()
        );
        for v in &top {
            print_verdict(v);
        }
    }

    let anomalous: Vec<_> = svmscreen::diag::convergence::log_snapshot()
        .into_iter()
        .filter(|s| s.anomalies > 0)
        .collect();
    if !anomalous.is_empty() {
        println!("\nsolver anomalies:");
        for s in &anomalous {
            println!(
                "  {} at lambda {}: {} anomaly(ies) ({} stall(s), {} divergence(s)) \
                 over {} iteration(s), rel_gap {:.2e}, converged={}",
                s.solver,
                fnum(s.lambda),
                s.anomalies,
                s.stalls,
                s.divergences,
                s.iterations,
                s.rel_gap,
                s.converged
            );
        }
    }

    if let Some(path) = raw.get("export") {
        let records = ledger.snapshot();
        svmscreen::report::diag::write_auto(path, &records)?;
        println!("\nwrote {path} ({} verdict(s))", records.len());
    }
    Ok(())
}

fn cmd_serve(cfg: &RunConfig) -> Result<()> {
    let p = load_problem(cfg)?;
    let server = ScreeningServer::start(
        p,
        ServerConfig {
            addr: cfg.addr.clone(),
            workers: cfg.workers,
            rule: cfg.rule,
            solve: cfg.solve_options(),
            shards: cfg.shards,
            ..Default::default()
        },
    )?;
    println!("screening service listening on {}", server.addr);
    if cfg.shards > 1 {
        println!(
            "sharded executor: {} shard(s) (see coordinator.shard.* in stats)",
            cfg.shards
        );
    }
    println!("protocol: one JSON object per line; try {{\"cmd\":\"info\"}}");
    // Long runs: arm the periodic stats dump when configured.
    if let Some(every) = svmscreen::telemetry::start_stats_dump_from_env() {
        println!("stats dump every {:.1}s (PALLAS_STATS_DUMP_SECS)", every.as_secs_f64());
    }
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
