//! Closed-form `λ_max` (Eq. 26) and the first feature(s) to enter the
//! model (§5 of the paper).
//!
//! At `w = 0` the optimal bias is `b* = (n₊ − n₋)/n` and
//!
//! ```text
//! λ_max = ‖ Σ_i (y_i − b*) x_i ‖_∞ = ‖ Xᵀ(y − b*·1) ‖_∞
//! ```
//!
//! The vector inside the norm, `m = Xᵀ(y − b*1)`, also identifies the
//! first feature(s) to become active as λ drops below `λ_max`: those
//! attaining the max magnitude.

use crate::data::FeatureMatrix;

/// Everything derived from the closed-form λ_max computation.
#[derive(Debug, Clone)]
pub struct LambdaMaxStats {
    /// The smallest λ with all-zero solution (Eq. 26).
    pub lambda_max: f64,
    /// Optimal bias at w = 0: `(n₊ − n₋)/n`.
    pub b_star: f64,
    /// The correlation vector `m_j = f_jᵀ(y − b*1)`.
    pub m_vec: Vec<f64>,
    /// Features attaining `|m_j| = λ_max` within `tol` — the first
    /// feature(s) to enter the model (§5).
    pub first_features: Vec<usize>,
}

/// Computes [`LambdaMaxStats`] in one pass over the columns (O(nnz)).
pub fn lambda_max_stats<X: FeatureMatrix>(x: &X, y: &[f64]) -> LambdaMaxStats {
    let n = x.n_samples();
    assert_eq!(y.len(), n, "label length");
    let n_pos = y.iter().filter(|v| **v > 0.0).count() as f64;
    let n_neg = n as f64 - n_pos;
    let b_star = (n_pos - n_neg) / n as f64;
    // residual r = y - b*·1
    let r: Vec<f64> = y.iter().map(|yi| yi - b_star).collect();
    let mut m_vec = vec![0.0; x.n_features()];
    x.matvec_t(&r, &mut m_vec);
    let lambda_max = m_vec.iter().fold(0.0f64, |a, v| a.max(v.abs()));
    let tol = 1e-12 * (1.0 + lambda_max);
    let first_features = m_vec
        .iter()
        .enumerate()
        .filter(|(_, v)| (v.abs() - lambda_max).abs() <= tol)
        .map(|(j, _)| j)
        .collect();
    LambdaMaxStats { lambda_max, b_star, m_vec, first_features }
}

/// Convenience: just the first features (§5).
pub fn first_features<X: FeatureMatrix>(x: &X, y: &[f64]) -> Vec<usize> {
    lambda_max_stats(x, y).first_features
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::data::synth::SynthSpec;
    use crate::data::FeatureData;
    use crate::testkit::assert_close;

    #[test]
    fn balanced_labels_zero_bias() {
        // y balanced -> b* = 0, m_j = f_j.y
        let x = DenseMatrix::from_cols(
            4,
            vec![vec![1.0, 1.0, -1.0, -1.0], vec![0.5, -0.5, 0.5, -0.5]],
        );
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let s = lambda_max_stats(&x, &y);
        assert_eq!(s.b_star, 0.0);
        // m0 = 1-1-1+1 = 0 ; m1 = 0.5+0.5+0.5+0.5 = 2
        assert_close(s.m_vec[0], 0.0, 1e-12, "m0");
        assert_close(s.m_vec[1], 2.0, 1e-12, "m1");
        assert_close(s.lambda_max, 2.0, 1e-12, "lambda_max");
        assert_eq!(s.first_features, vec![1]);
    }

    #[test]
    fn unbalanced_bias() {
        let x = DenseMatrix::from_cols(3, vec![vec![1.0, 2.0, 3.0]]);
        let y = vec![1.0, 1.0, -1.0];
        let s = lambda_max_stats(&FeatureData::Dense(x), &y);
        assert_close(s.b_star, 1.0 / 3.0, 1e-12, "b*");
        // m = (1-1/3)*1 + (1-1/3)*2 + (-1-1/3)*3 = 2/3 + 4/3 - 4 = -2
        assert_close(s.m_vec[0], -2.0, 1e-12, "m");
        assert_close(s.lambda_max, 2.0, 1e-12, "lambda_max");
    }

    #[test]
    fn consistent_on_synthetic_sparse() {
        let ds = SynthSpec::text(80, 300, 21).generate();
        let s = lambda_max_stats(&ds.x, &ds.y);
        assert!(s.lambda_max > 0.0);
        assert!(!s.first_features.is_empty());
        // first features attain the max
        for &j in &s.first_features {
            assert_close(s.m_vec[j].abs(), s.lambda_max, 1e-9, "attains max");
        }
        assert_eq!(first_features(&ds.x, &ds.y), s.first_features);
    }
}
