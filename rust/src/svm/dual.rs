//! Primal → dual map (Eq. 20), dual objective and the duality gap.
//!
//! From Eq. (13) the dual function is `D(α) = 1ᵀα − ½‖α‖²` subject to
//! `|f̂_jᵀα| ≤ λ`, `Σ α_i y_i = 0`, `α ≥ 0` (Eq. 18; `θ = α/λ` gives
//! Eq. 19). Strong duality holds, so for any primal `(w, b)` and any
//! dual-feasible `α`:
//!
//! ```text
//! gap(w, b, α) = P(w, b) − D(α) ≥ P(w, b) − P(w*, b*) ≥ 0
//! ```
//!
//! which is the solver's *certificate of optimality* and the precision
//! knob for screening-safety experiments.
//!
//! ## Constructing a feasible α from a primal point
//!
//! Eq. (20) suggests `α̃ = ξ`. Three constraints must hold:
//! * `α ≥ 0` — automatic (`ξ` is a max with 0);
//! * `Σ α_i y_i = 0` — holds **iff the bias is exactly optimal** for the
//!   current `w` (that is precisely the condition `∂h/∂b = 0`), so this
//!   module always re-optimizes `b` via [`crate::svm::objective::optimal_bias`]
//!   before mapping;
//! * `|f̂_jᵀα| ≤ λ` — enforced by scaling `α = s·α̃` with the *optimal*
//!   feasible scale `s = clamp(1ᵀα̃/‖α̃‖², 0, λ/max_j|f̂_jᵀα̃|)`, which
//!   maximizes the concave `D(s·α̃)` over the feasible segment (scaling
//!   preserves the sign and equality constraints).

use crate::data::FeatureMatrix;
use crate::svm::objective::{margins, optimal_bias, Margins};

/// A dual-feasible point with its provenance.
#[derive(Debug, Clone)]
pub struct DualPoint {
    /// Dual variables `α` (feasible for the given λ).
    pub alpha: Vec<f64>,
    /// The (re-optimized) bias at which `α` was constructed.
    pub b: f64,
    /// λ the point is feasible for.
    pub lambda: f64,
}

impl DualPoint {
    /// `θ = α/λ` — the normalized dual variable of Eq. (19).
    pub fn theta(&self) -> Vec<f64> {
        self.alpha.iter().map(|a| a / self.lambda).collect()
    }
}

/// Gap diagnostics for one primal/dual pair.
#[derive(Debug, Clone, Copy)]
pub struct GapReport {
    /// Primal objective `P(w, b)`.
    pub primal: f64,
    /// Dual objective `D(α)` of the constructed feasible point.
    pub dual: f64,
    /// `P − D ≥ 0` (clamped at 0 against float noise).
    pub gap: f64,
    /// `gap / max(1, |P|)`.
    pub rel_gap: f64,
    /// The scaling `s` applied to `α̃ = ξ` (1 ⇒ already feasible).
    pub scale: f64,
    /// `max_j |f̂_jᵀ α̃|` before scaling.
    pub max_corr: f64,
}

/// Dual objective `D(α) = 1ᵀα − ½‖α‖²`.
pub fn dual_objective(alpha: &[f64]) -> f64 {
    let mut s = 0.0;
    let mut q = 0.0;
    for &a in alpha {
        s += a;
        q += a * a;
    }
    s - 0.5 * q
}

/// The Eq. (20) map: `θ_i = max(0, 1 − y_i(wᵀx_i + b)) / λ`.
///
/// This is exact *at the optimum*; away from it the result is a
/// candidate that [`duality_gap`] makes feasible.
pub fn theta_from_primal<X: FeatureMatrix>(
    x: &X,
    y: &[f64],
    w: &[f64],
    b: f64,
    lambda: f64,
) -> Vec<f64> {
    let mar = margins(x, y, w, b);
    mar.xi.iter().map(|xi| xi / lambda).collect()
}

/// `max_j |f̂_jᵀ α| = max_j |f_jᵀ (y∘α)|` — the dual-constraint residual.
pub fn max_abs_correlation<X: FeatureMatrix>(x: &X, y: &[f64], alpha: &[f64]) -> f64 {
    let ya: Vec<f64> = y.iter().zip(alpha).map(|(yi, ai)| yi * ai).collect();
    let mut best = 0.0f64;
    for j in 0..x.n_features() {
        best = best.max(x.col_dot(j, &ya).abs());
    }
    best
}

/// Computes the duality gap at `w` (bias re-optimized internally).
///
/// Returns the gap report, the constructed feasible [`DualPoint`] and the
/// margins at the re-optimized bias (reusable by the caller).
pub fn duality_gap<X: FeatureMatrix>(
    x: &X,
    y: &[f64],
    w: &[f64],
    lambda: f64,
) -> (GapReport, DualPoint, Margins) {
    let n = x.n_samples();
    let mut mar = margins(x, y, w, 0.0);
    let b = optimal_bias(y, &mar.scores);
    mar.update_bias(y, b);

    let primal = mar.loss() + lambda * w.iter().map(|v| v.abs()).sum::<f64>();

    // Candidate alpha = xi; optimal feasible scaling.
    let alpha_tilde = &mar.xi;
    let sum: f64 = alpha_tilde.iter().sum();
    let sq: f64 = alpha_tilde.iter().map(|a| a * a).sum();
    let max_corr = max_abs_correlation(x, y, alpha_tilde);
    let s_unconstrained = if sq > 0.0 { sum / sq } else { 0.0 };
    let s_max = if max_corr > lambda { lambda / max_corr } else { 1.0_f64.max(s_unconstrained) };
    // D(s·α̃) is concave in s; maximize over [0, s_cap] where s_cap keeps
    // feasibility. When already feasible (max_corr <= λ) we may still
    // scale up as long as s·max_corr <= λ.
    let s_cap = if max_corr > 0.0 { lambda / max_corr } else { f64::INFINITY };
    let s = s_unconstrained.clamp(0.0, s_cap.min(s_max.max(1.0)));

    let alpha: Vec<f64> = alpha_tilde.iter().map(|a| s * a).collect();
    let dual = dual_objective(&alpha);
    let gap = (primal - dual).max(0.0);
    let report = GapReport {
        primal,
        dual,
        gap,
        rel_gap: gap / primal.abs().max(1.0),
        scale: s,
        max_corr,
    };
    debug_assert_eq!(alpha.len(), n);
    (report, DualPoint { alpha, b, lambda }, mar)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::svm::lambda_max::lambda_max_stats;
    use crate::testkit::{assert_close, assert_dominates};

    #[test]
    fn dual_objective_by_hand() {
        // D = sum - 0.5*normsq = (1+2) - 0.5*(1+4) = 0.5
        assert_close(dual_objective(&[1.0, 2.0]), 0.5, 1e-12, "D");
    }

    #[test]
    fn gap_zero_at_lambda_max() {
        // At λ = λ_max the optimum is w = 0, b = b*; the mapped dual point
        // must certify it: gap(0) == 0 (within float noise).
        let ds = SynthSpec::dense(60, 20, 2).generate();
        let s = lambda_max_stats(&ds.x, &ds.y);
        let w = vec![0.0; 20];
        let (rep, dp, _) = duality_gap(&ds.x, &ds.y, &w, s.lambda_max);
        assert!(rep.rel_gap < 1e-9, "rel gap {} at lambda_max", rep.rel_gap);
        assert_close(dp.b, s.b_star, 1e-9, "bias matches closed form");
        // theta at lambda_max from Eq.(20): (1 - y b*)/lambda_max
        let theta = dp.theta();
        for i in 0..ds.n() {
            let expect = (1.0 - ds.y[i] * s.b_star).max(0.0) / s.lambda_max;
            assert_close(theta[i], expect, 1e-9, "theta_i");
        }
    }

    #[test]
    fn gap_nonnegative_and_dual_feasible() {
        let ds = SynthSpec::text(50, 120, 4).generate();
        let s = lambda_max_stats(&ds.x, &ds.y);
        let lambda = 0.5 * s.lambda_max;
        // an arbitrary (non-optimal) primal point
        let mut w = vec![0.0; 120];
        w[3] = 0.2;
        w[70] = -0.1;
        let (rep, dp, _) = duality_gap(&ds.x, &ds.y, &w, lambda);
        assert!(rep.gap >= 0.0);
        assert_dominates(rep.primal, rep.dual, 1e-9, "P >= D");
        // feasibility of constructed alpha
        assert!(dp.alpha.iter().all(|&a| a >= 0.0));
        let eq: f64 = dp.alpha.iter().zip(&ds.y).map(|(a, y)| a * y).sum();
        assert!(eq.abs() < 1e-8, "sum alpha y = {eq}");
        let mc = max_abs_correlation(&ds.x, &ds.y, &dp.alpha);
        assert!(mc <= lambda * (1.0 + 1e-9), "max corr {mc} > lambda {lambda}");
    }

    #[test]
    fn theta_map_matches_margins() {
        let ds = SynthSpec::dense(30, 10, 6).generate();
        let w = vec![0.05; 10];
        let lambda = 1.3;
        let theta = theta_from_primal(&ds.x, &ds.y, &w, 0.1, lambda);
        let mar = margins(&ds.x, &ds.y, &w, 0.1);
        for i in 0..30 {
            assert_close(theta[i], mar.xi[i] / lambda, 1e-12, "theta=xi/lambda");
        }
    }

    #[test]
    fn scaling_improves_or_keeps_dual_value() {
        // The chosen scale must be at least as good as the naive
        // "just make it feasible" scale s = λ / max_corr.
        let ds = SynthSpec::dense(40, 15, 8).generate();
        let s = lambda_max_stats(&ds.x, &ds.y);
        let lambda = 0.9 * s.lambda_max;
        let w = vec![0.0; 15];
        let (rep, dp, mar) = duality_gap(&ds.x, &ds.y, &w, lambda);
        let naive = (lambda / rep.max_corr).min(1.0);
        let alpha_naive: Vec<f64> = mar.xi.iter().map(|a| naive * a).collect();
        assert!(rep.dual >= dual_objective(&alpha_naive) - 1e-12);
        assert_eq!(dp.lambda, lambda);
    }
}
