//! The [`Problem`] container: a dataset bound to the sparse-SVM model,
//! with the λ_max statistics cached.

use crate::data::cache::FeatureCache;
use crate::data::dataset::Dataset;
use crate::data::{FeatureData, FeatureMatrix};
use crate::svm::dual::DualPoint;
use crate::svm::lambda_max::{lambda_max_stats, LambdaMaxStats};
use std::sync::OnceLock;

/// A sparse-SVM training problem: features, labels and the cached
/// closed-form quantities of §4/§5 of the paper.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Feature matrix (n × m).
    pub x: FeatureData,
    /// Labels (±1).
    pub y: Vec<f64>,
    /// Dataset name (for reports).
    pub name: String,
    lm: LambdaMaxStats,
    cache: OnceLock<FeatureCache>,
}

impl Problem {
    /// Binds a dataset (cheap clone of labels; features are moved).
    pub fn new(name: impl Into<String>, x: FeatureData, y: Vec<f64>) -> Self {
        let lm = lambda_max_stats(&x, &y);
        Problem { x, y, name: name.into(), lm, cache: OnceLock::new() }
    }

    /// The path-wide per-feature statistics cache
    /// ([`crate::data::cache::FeatureCache`]): built lazily with one
    /// O(nnz) pass on first use, then shared by screening sweeps, the
    /// CD curvature vector and the block partitioner, and *remapped*
    /// (never recomputed) onto each reduced problem.
    pub fn cache(&self) -> &FeatureCache {
        self.cache.get_or_init(|| FeatureCache::build(&self.x, &self.y))
    }

    /// Builds from a [`Dataset`] by cloning its storage.
    pub fn from_dataset(ds: &Dataset) -> Self {
        Problem::new(ds.name.clone(), ds.x.clone(), ds.y.clone())
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.x.n_samples()
    }

    /// Number of features.
    pub fn m(&self) -> usize {
        self.x.n_features()
    }

    /// The smallest λ with all-zero solution (Eq. 26).
    pub fn lambda_max(&self) -> f64 {
        self.lm.lambda_max
    }

    /// Optimal bias at `w = 0`.
    pub fn b_star(&self) -> f64 {
        self.lm.b_star
    }

    /// Full λ_max statistics (correlation vector, first features).
    pub fn lambda_max_stats(&self) -> &LambdaMaxStats {
        &self.lm
    }

    /// The exact dual point at `λ = λ_max` (footnote 1 of the paper):
    /// `θ_i = (1 − y_i b*)/λ_max`, which is ≥ 0 because `b* ∈ [−1, 1]`.
    pub fn theta_at_lambda_max(&self) -> DualPoint {
        let lam = self.lm.lambda_max;
        let alpha: Vec<f64> = self
            .y
            .iter()
            .map(|yi| (1.0 - yi * self.lm.b_star).max(0.0))
            .collect();
        DualPoint { alpha, b: self.lm.b_star, lambda: lam }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::svm::dual::max_abs_correlation;
    use crate::testkit::assert_close;

    #[test]
    fn cached_stats_match_direct() {
        let ds = SynthSpec::dense(40, 12, 10).generate();
        let p = Problem::from_dataset(&ds);
        let direct = lambda_max_stats(&p.x, &p.y);
        assert_eq!(p.lambda_max(), direct.lambda_max);
        assert_eq!(p.b_star(), direct.b_star);
        assert_eq!(p.n(), 40);
        assert_eq!(p.m(), 12);
        assert!(p.name.contains("synth-dense"));
    }

    #[test]
    fn feature_cache_lazy_and_stable() {
        let ds = SynthSpec::text(30, 60, 14).generate();
        let p = Problem::from_dataset(&ds);
        let c1 = p.cache();
        assert_eq!(c1.len(), p.m());
        assert_eq!(c1.nnz, p.x.nnz());
        // Same instance on repeat calls (lazy init, not a rebuild).
        assert!(std::ptr::eq(c1, p.cache()));
    }

    #[test]
    fn theta_at_lambda_max_is_dual_feasible() {
        let ds = SynthSpec::text(60, 150, 12).generate();
        let p = Problem::from_dataset(&ds);
        let dp = p.theta_at_lambda_max();
        // alpha >= 0
        assert!(dp.alpha.iter().all(|&a| a >= 0.0));
        // equality constraint
        let eq: f64 = dp.alpha.iter().zip(&p.y).map(|(a, y)| a * y).sum();
        assert!(eq.abs() < 1e-9, "sum alpha y = {eq}");
        // |fhat' alpha| <= lambda_max with equality attained at the first feature
        let mc = max_abs_correlation(&p.x, &p.y, &dp.alpha);
        assert_close(mc, p.lambda_max(), 1e-9, "max corr == lambda_max");
        // theta scaling
        let theta = dp.theta();
        assert_close(
            theta[0] * p.lambda_max(),
            dp.alpha[0],
            1e-12,
            "theta = alpha/lambda",
        );
    }
}
