//! SVM core: the L1-regularized L2-loss SVM of the paper.
//!
//! * [`problem`] — the [`problem::Problem`] container binding data to the
//!   model, with cached `λ_max` (Eq. 26) and the dual point at `λ_max`.
//! * [`objective`] — primal objective `h(w,b) + λ‖w‖₁` (Eq. 23) and its
//!   gradient (Eq. 24–25), plus the exact unpenalized-bias step.
//! * [`lambda_max`] — closed-form `λ_max` (Eq. 26) and the first
//!   feature(s) to enter the model (§5).
//! * [`dual`] — the primal→dual map (Eq. 20), dual feasibility scaling,
//!   and the duality gap used as the solver's certificate of optimality.
//! * [`kkt`] — KKT residual checks (Eq. 21–22) used by safety audits.

pub mod dual;
pub mod kkt;
pub mod lambda_max;
pub mod objective;
pub mod problem;

pub use dual::{dual_objective, duality_gap, theta_from_primal, DualPoint};
pub use lambda_max::{first_features, lambda_max_stats, LambdaMaxStats};
pub use objective::{margins, optimal_bias, primal_gradient, primal_objective, Margins};
pub use problem::Problem;
