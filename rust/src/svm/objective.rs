//! Primal objective, gradient and the exact bias step.
//!
//! The unconstrained primal (Eq. 23):
//!
//! ```text
//! min_{w,b}  h(w,b) + λ‖w‖₁,
//! h(w,b) = ½ Σ_i max(1 − y_i(wᵀx_i + b), 0)²
//! ```
//!
//! with gradient (Eq. 24–25)
//!
//! ```text
//! ∇_w h = −Σ_i ξ_i y_i x_i = −Xᵀ(ξ∘y),    ∂h/∂b = −Σ_i ξ_i y_i,
//! ξ_i = max(1 − y_i(wᵀx_i + b), 0).
//! ```
//!
//! [`optimal_bias`] solves `∂h/∂b = 0` exactly for fixed `w` — a
//! piecewise-linear monotone root find. Keeping `b` exactly optimal is
//! what makes the candidate dual point `α = ξ` satisfy the equality
//! constraint `Σ α_i y_i = 0` (Eq. 17), which the duality-gap
//! construction in [`crate::svm::dual`] relies on.

use crate::data::FeatureMatrix;

/// Per-sample margin state at a primal point `(w, b)`.
#[derive(Debug, Clone)]
pub struct Margins {
    /// Raw scores `z_i = wᵀx_i` (bias *not* included).
    pub scores: Vec<f64>,
    /// Hinge slacks `ξ_i = max(1 − y_i(z_i + b), 0)` — also the candidate
    /// dual variable `α_i` (Eq. 20).
    pub xi: Vec<f64>,
    /// The bias used to compute `xi`.
    pub b: f64,
}

impl Margins {
    /// Recomputes `xi` from stored scores for a new bias.
    pub fn update_bias(&mut self, y: &[f64], b: f64) {
        self.b = b;
        for i in 0..self.xi.len() {
            self.xi[i] = (1.0 - y[i] * (self.scores[i] + b)).max(0.0);
        }
    }

    /// Loss term `½ Σ ξ²`.
    pub fn loss(&self) -> f64 {
        0.5 * self.xi.iter().map(|v| v * v).sum::<f64>()
    }
}

/// Computes margins at `(w, b)`. O(Σ_{w_j≠0} nnz_j + n).
pub fn margins<X: FeatureMatrix>(x: &X, y: &[f64], w: &[f64], b: f64) -> Margins {
    let n = x.n_samples();
    let mut scores = vec![0.0; n];
    x.matvec(w, &mut scores);
    let mut m = Margins { scores, xi: vec![0.0; n], b };
    m.update_bias(y, b);
    m
}

/// Primal objective `h(w,b) + λ‖w‖₁`.
pub fn primal_objective<X: FeatureMatrix>(x: &X, y: &[f64], w: &[f64], b: f64, lambda: f64) -> f64 {
    let m = margins(x, y, w, b);
    m.loss() + lambda * w.iter().map(|v| v.abs()).sum::<f64>()
}

/// Gradient of the smooth part `h`: returns `(∇_w h, ∂h/∂b)`.
///
/// `∇_w h[j] = −f_jᵀ(ξ∘y)`. Cost O(nnz(X)).
pub fn primal_gradient<X: FeatureMatrix>(x: &X, y: &[f64], mar: &Margins) -> (Vec<f64>, f64) {
    let n = x.n_samples();
    let mut xiy = vec![0.0; n];
    let mut gb = 0.0;
    for i in 0..n {
        xiy[i] = mar.xi[i] * y[i];
        gb -= xiy[i];
    }
    let mut gw = vec![0.0; x.n_features()];
    x.matvec_t(&xiy, &mut gw);
    for g in gw.iter_mut() {
        *g = -*g;
    }
    (gw, gb)
}

/// Exact minimization of `h(w, b)` over `b` for fixed scores.
///
/// `g(b) = −∂h/∂b = Σ max(1 − y_i(z_i + b), 0) y_i` is continuous,
/// piecewise-linear and non-increasing in `b`, with slope
/// `g'(b) = −|{i : margin violated}|` wherever differentiable. The root
/// is found by **safeguarded Newton**: Newton steps on the piecewise
/// structure land exactly on the root once the active set stabilizes
/// (typically ≤ 6 O(n) evaluations), with a shrinking bisection bracket
/// as the fallback guarantee. (Replaced a 200-step pure bisection —
/// `optimal_bias` was 13.5% of solve time; EXPERIMENTS.md §Perf P2.)
pub fn optimal_bias(y: &[f64], scores: &[f64]) -> f64 {
    optimal_bias_from(y, scores, 0.0)
}

/// [`optimal_bias`] with a warm start: the bracket grows geometrically
/// out from `b_init`, so when the previous epoch's bias is passed (the
/// CD solver does) only a handful of O(n) evaluations are needed
/// (EXPERIMENTS.md §Perf P3).
pub fn optimal_bias_from(y: &[f64], scores: &[f64], b_init: f64) -> f64 {
    // Evaluates g(b) and the active count (−slope) in one pass.
    let eval = |b: f64| -> (f64, usize) {
        let mut acc = 0.0;
        let mut active = 0usize;
        for i in 0..y.len() {
            let xi = 1.0 - y[i] * (scores[i] + b);
            if xi > 0.0 {
                acc += xi * y[i];
                active += 1;
            }
        }
        (acc, active)
    };
    // Directional bracket from the warm start: g is non-increasing, so
    // the sign of g(b_init) says which way the root lies; walk that way
    // with doubling steps until the sign flips (2–3 evals typical when
    // warm-started from the previous epoch's bias).
    let (g0, active0) = eval(b_init);
    if g0 == 0.0 {
        return b_init;
    }
    // First guess for the walk scale: a Newton step if the slope exists.
    let mut step = if active0 > 0 { (g0.abs() / active0 as f64).max(1e-3) } else { 1.0 };
    let (mut lo, mut hi);
    if g0 > 0.0 {
        // Root to the right.
        lo = b_init;
        hi = b_init + step;
        let mut ghi = eval(hi).0;
        let mut tries = 0;
        while ghi > 0.0 {
            lo = hi;
            step *= 2.0;
            hi += step;
            ghi = eval(hi).0;
            tries += 1;
            if tries > 128 {
                return hi; // degenerate (all one class)
            }
        }
    } else {
        // Root to the left.
        hi = b_init;
        lo = b_init - step;
        let mut glo = eval(lo).0;
        let mut tries = 0;
        while glo < 0.0 {
            hi = lo;
            step *= 2.0;
            lo -= step;
            glo = eval(lo).0;
            tries += 1;
            if tries > 128 {
                return lo; // degenerate
            }
        }
    }
    let mut b = 0.5 * (lo + hi);
    for _ in 0..100 {
        let (gb, active) = eval(b);
        if gb == 0.0 {
            return b;
        }
        // Shrink the bracket with the sign.
        if gb > 0.0 {
            lo = b;
        } else {
            hi = b;
        }
        if hi - lo < 1e-15 * (1.0 + hi.abs()) {
            break;
        }
        // Newton candidate (slope = -active); bisect when flat or when
        // the candidate escapes the bracket.
        let candidate = if active > 0 { b + gb / active as f64 } else { f64::NAN };
        b = if candidate.is_finite() && candidate > lo && candidate < hi {
            candidate
        } else {
            0.5 * (lo + hi)
        };
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::data::synth::{Pcg32, SynthSpec};
    use crate::data::{FeatureData, FeatureMatrix};
    use crate::testkit::{assert_close, property};

    fn toy() -> (FeatureData, Vec<f64>) {
        // 4 samples, 2 features
        let x = DenseMatrix::from_cols(
            4,
            vec![vec![1.0, -1.0, 0.5, 0.0], vec![0.0, 2.0, -1.0, 1.0]],
        );
        (FeatureData::Dense(x), vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn objective_by_hand() {
        let (x, y) = toy();
        let w = [1.0, -0.5];
        let b = 0.1;
        // z = Xw: [1.0, -2.0, 1.0, -0.5]
        // xi_i = max(1 - y_i(z_i+b), 0):
        //   i0: 1 - (1.1)        = -0.1 -> 0
        //   i1: 1 - (-1)(-1.9)   = -0.9 -> 0
        //   i2: 1 - (1.1)        = -0.1 -> 0
        //   i3: 1 - (-1)(-0.4)   = 0.6
        let p = primal_objective(&x, &y, &w, b, 2.0);
        assert_close(p, 0.5 * 0.36 + 2.0 * 1.5, 1e-12, "objective");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let ds = SynthSpec::dense(25, 8, 3).generate();
        let mut rng = Pcg32::seeded(17);
        let w: Vec<f64> = (0..8).map(|_| 0.3 * rng.gaussian()).collect();
        let b = 0.2;
        let mar = margins(&ds.x, &ds.y, &w, b);
        let (gw, gb) = primal_gradient(&ds.x, &ds.y, &mar);
        let eps = 1e-6;
        let h0 = margins(&ds.x, &ds.y, &w, b).loss();
        for j in 0..8 {
            let mut wp = w.clone();
            wp[j] += eps;
            let hp = margins(&ds.x, &ds.y, &wp, b).loss();
            let fd = (hp - h0) / eps;
            assert_close(gw[j], fd, 1e-4, &format!("grad w[{j}]"));
        }
        let hp = margins(&ds.x, &ds.y, &w, b + eps).loss();
        assert_close(gb, (hp - h0) / eps, 1e-4, "grad b");
    }

    #[test]
    fn optimal_bias_zeroes_grad_b() {
        let ds = SynthSpec::dense(40, 6, 5).generate();
        let w = vec![0.1; 6];
        let mut mar = margins(&ds.x, &ds.y, &w, 0.0);
        let b = optimal_bias(&ds.y, &mar.scores);
        mar.update_bias(&ds.y, b);
        let (_, gb) = primal_gradient(&ds.x, &ds.y, &mar);
        assert!(gb.abs() < 1e-9, "grad_b at optimal bias: {gb}");
        // equality constraint of the dual holds: sum xi*y = 0
        let s: f64 = mar.xi.iter().zip(&ds.y).map(|(a, b)| a * b).sum();
        assert!(s.abs() < 1e-9, "sum xi y = {s}");
    }

    #[test]
    fn optimal_bias_is_minimizer_property() {
        property("optimal-bias-minimizer", 11, 20, |rng| {
            let n = 10 + rng.below(30);
            let scores: Vec<f64> = (0..n).map(|_| 2.0 * rng.gaussian()).collect();
            let mut y: Vec<f64> =
                (0..n).map(|_| if rng.f64() < 0.5 { 1.0 } else { -1.0 }).collect();
            y[0] = 1.0;
            y[1] = -1.0;
            let loss = |b: f64| -> f64 {
                scores
                    .iter()
                    .zip(&y)
                    .map(|(z, yi)| {
                        let xi = (1.0 - yi * (z + b)).max(0.0);
                        0.5 * xi * xi
                    })
                    .sum()
            };
            let b = optimal_bias(&y, &scores);
            let l0 = loss(b);
            for db in [-0.1, -1e-3, 1e-3, 0.1] {
                assert!(
                    loss(b + db) >= l0 - 1e-12,
                    "bias {b} not a minimizer: loss({}) = {} < {}",
                    b + db,
                    loss(b + db),
                    l0
                );
            }
        });
    }

    #[test]
    fn bias_at_w0_matches_closed_form() {
        // Paper §4: at w=0, b* = (n+ - n-)/n.
        let ds = SynthSpec::text(60, 100, 7).generate();
        let scores = vec![0.0; 60];
        let b = optimal_bias(&ds.y, &scores);
        let expect = (ds.n_pos() as f64 - ds.n_neg() as f64) / 60.0;
        assert_close(b, expect, 1e-9, "b* at w=0");
    }

    #[test]
    fn margins_bias_update_consistent() {
        let (x, y) = toy();
        let w = [0.5, 0.5];
        let m1 = margins(&x, &y, &w, 0.7);
        let mut m2 = margins(&x, &y, &w, 0.0);
        m2.update_bias(&y, 0.7);
        assert_eq!(m1.xi, m2.xi);
        assert_eq!(x.n_samples(), 4);
    }
}
