//! KKT residual checks — the optimality structure of Eq. (21)/(22).
//!
//! At the optimum, with `θ = α/λ` (Eq. 19/20):
//!
//! ```text
//! θᵀf̂_j = sign(w_j)      if w_j ≠ 0      (active features)
//! θᵀf̂_j ∈ [−1, +1]       if w_j = 0      (inactive features)
//! ```
//!
//! The audit quantifies how far a claimed solution is from satisfying
//! these — used by the safety experiments (a screened feature that turns
//! out active would show up here as a violation) and by solver tests.

use crate::data::FeatureMatrix;

/// Result of a KKT audit at a claimed optimum.
#[derive(Debug, Clone)]
pub struct KktReport {
    /// `max_j |θᵀf̂_j|` over inactive features (should be ≤ 1).
    pub max_inactive: f64,
    /// `max_j | |θᵀf̂_j| − 1 |` over active features (should be 0).
    pub max_active_dev: f64,
    /// Active features whose `sign(θᵀf̂_j) ≠ sign(w_j)`.
    pub sign_violations: usize,
    /// Inactive features with `|θᵀf̂_j| > 1 + tol`.
    pub inactive_violations: usize,
    /// Number of active features.
    pub n_active: usize,
    /// Tolerance used.
    pub tol: f64,
}

impl KktReport {
    /// True when no violation exceeded the tolerance.
    pub fn ok(&self) -> bool {
        self.sign_violations == 0
            && self.inactive_violations == 0
            && self.max_active_dev <= self.tol
    }
}

/// Audits `(w, θ)` against Eq. (22). `theta` must be the dual point for
/// the *same* λ as `w`.
pub fn kkt_audit<X: FeatureMatrix>(
    x: &X,
    y: &[f64],
    w: &[f64],
    theta: &[f64],
    tol: f64,
) -> KktReport {
    let ytheta: Vec<f64> = y.iter().zip(theta).map(|(yi, ti)| yi * ti).collect();
    let mut max_inactive = 0.0f64;
    let mut max_active_dev = 0.0f64;
    let mut sign_violations = 0;
    let mut inactive_violations = 0;
    let mut n_active = 0;
    for j in 0..x.n_features() {
        let corr = x.col_dot(j, &ytheta); // θᵀ f̂_j
        if w[j] != 0.0 {
            n_active += 1;
            max_active_dev = max_active_dev.max((corr.abs() - 1.0).abs());
            if corr.signum() != w[j].signum() {
                sign_violations += 1;
            }
        } else {
            max_inactive = max_inactive.max(corr.abs());
            if corr.abs() > 1.0 + tol {
                inactive_violations += 1;
            }
        }
    }
    KktReport {
        max_inactive,
        max_active_dev,
        sign_violations,
        inactive_violations,
        n_active,
        tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    #[test]
    fn clean_point_passes() {
        // Construct a consistent toy: f0 with theta^T fhat_0 = 1 (active,
        // w_0 > 0), f1 with small correlation (inactive).
        let y = vec![1.0, -1.0];
        let theta = vec![0.5, 0.5];
        // ytheta = [0.5, -0.5]; want f0 . ytheta = 1 -> f0 = [1, -1]
        let x = DenseMatrix::from_cols(2, vec![vec![1.0, -1.0], vec![0.4, 0.4]]);
        let w = vec![2.0, 0.0];
        let rep = kkt_audit(&x, &y, &w, &theta, 1e-9);
        assert!(rep.ok(), "{rep:?}");
        assert_eq!(rep.n_active, 1);
        assert!(rep.max_inactive <= 0.01);
    }

    #[test]
    fn detects_sign_violation() {
        let y = vec![1.0, -1.0];
        let theta = vec![0.5, 0.5];
        let x = DenseMatrix::from_cols(2, vec![vec![1.0, -1.0]]);
        let w = vec![-2.0]; // wrong sign vs corr = +1
        let rep = kkt_audit(&x, &y, &w, &theta, 1e-9);
        assert_eq!(rep.sign_violations, 1);
        assert!(!rep.ok());
    }

    #[test]
    fn detects_inactive_violation() {
        let y = vec![1.0, -1.0];
        let theta = vec![1.0, 1.0];
        // corr = f.(y∘theta) = [1,-1].[1,-1] = 2 > 1, but w = 0
        let x = DenseMatrix::from_cols(2, vec![vec![1.0, -1.0]]);
        let w = vec![0.0];
        let rep = kkt_audit(&x, &y, &w, &theta, 1e-6);
        assert_eq!(rep.inactive_violations, 1);
        assert!(rep.max_inactive > 1.0);
        assert!(!rep.ok());
    }
}
