//! # svmscreen — Safe and Efficient Screening for Sparse SVM
//!
//! A production-grade reproduction of *"Safe and Efficient Screening for
//! Sparse Support Vector Machine"* (Zhao & Liu, KDD 2014) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: regularization-path runner with
//!   sequential safe screening, warm-started solvers, a block-parallel
//!   screening executor, and a batched screening service.
//! * **L2 (python/compile/model.py, build-time only)** — JAX graphs for the
//!   screening pass and the SVM objective/gradient, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time only)** — the Pallas kernel
//!   computing the per-feature screening bound as an MXU panel matmul.
//!
//! The rust binary is self-contained after `make artifacts`: it loads the
//! HLO artifacts through the PJRT C API (`xla` crate) and never calls
//! Python on the hot path. All screening math is *also* implemented
//! natively in rust ([`screening`]) so the system runs without artifacts
//! and so the PJRT path can be cross-validated against a second
//! implementation.
//!
//! ## Quickstart
//!
//! ```no_run
//! use svmscreen::prelude::*;
//!
//! // A synthetic text-like classification dataset.
//! let ds = svmscreen::data::synth::SynthSpec::text(2000, 5000, 42).generate();
//! let problem = Problem::from_dataset(&ds);
//!
//! // Train a 20-point regularization path with safe screening.
//! let grid = svmscreen::path::grid::geometric(problem.lambda_max(), 0.05, 20).unwrap();
//! let cfg = svmscreen::path::runner::PathConfig::default();
//! let report = svmscreen::path::runner::run_path(&problem, &grid, &cfg).unwrap();
//! println!("{}", report.summary_table());
//! ```
//!
//! ## Path-wide feature cache
//!
//! Every [`svm::problem::Problem`] lazily builds a
//! [`data::cache::FeatureCache`] — one O(nnz) pass materializing the
//! λ-independent per-column stats (`fᵀy`, `fᵀ1`, `‖f‖²`, nnz). The
//! cache is built **once per problem** and then *remapped* (never
//! recomputed) onto every reduced problem along a path. Consumers:
//! screening sweeps shrink to a single θ-dependent dot per feature,
//! coordinate descent serves its curvature vector `H_j = ‖f_j‖²` from
//! the cache, and the block partitioner reads cached nnz. The path
//! runner also reuses the previous step's reduced matrix whenever the
//! kept set only tightens ([`solver::reduced::ReducedProblem`]
//! incremental builds), fanning gathers out over
//! [`path::runner::PathConfig::workers`] threads (`--workers N`).
//! Reuse efficacy is metered as `path.cache.hits` /
//! `path.cache.misses` / `path.gather_bytes` and the
//! `path.step.gather_seconds` histogram — all visible via
//! `{"cmd":"stats"}` and the Prometheus rendering. Cached screening is
//! bit-identical to the uncached path (see the cache module docs for
//! the accumulation-order contract).
//!
//! ## Observability
//!
//! Every hot layer (solvers, screening sweeps, path steps, the
//! coordinator) reports into the in-tree [`telemetry`] subsystem — a
//! global metrics registry (counters / gauges / log-scale latency
//! histograms with p50/p90/p99), RAII wall-time spans, and leveled
//! event sinks. Configuration is environment-driven:
//!
//! * **`PALLAS_LOG`** = `error` | `warn` | `info` | `debug` | `trace` |
//!   `off` — stderr verbosity (default `warn`). `PALLAS_LOG=debug`
//!   shows span-annotated begin/end lines for path runs and server
//!   requests.
//! * **`PALLAS_LOG_JSON`** = `path.jsonl` — append every event as one
//!   JSON object per line (machine-readable traces).
//! * **`PALLAS_TRACE_CAPACITY`** = `N` — trace-ring capacity in records
//!   (default 16384; `0` disables the recorder).
//! * **`PALLAS_TRACE_OUT`** = `trace.json` — write the recorded span
//!   timeline as a Chrome trace-event file (benches and any run).
//! * **`PALLAS_STATS_DUMP_SECS`** = `N` — `serve` only: emit a full
//!   stats snapshot through the sinks every N seconds
//!   ([`telemetry::start_stats_dump_from_env`]).
//! * **`PALLAS_SHARDS`** = `K` — `serve` only: default for `--shards`;
//!   `K > 1` screens batches across K nnz-balanced feature shards with
//!   per-shard cache reuse ([`coordinator::ShardedScreener`],
//!   `coordinator.shard.*` metrics).
//!
//! Beyond aggregate metrics, a bounded trace ring
//! ([`telemetry::trace`]) captures every completed span (name, label,
//! start, duration, thread, nesting depth) plus warn/error instants.
//! Three surfaces drain it: the `--trace-out FILE` CLI flag (Chrome
//! trace-event JSON, loadable in Perfetto or `chrome://tracing`), the
//! `{"cmd":"trace"}` protocol command (raw records, or the Chrome
//! document with `"chrome":true`), and `PALLAS_TRACE_OUT` for benches.
//!
//! The screening service exposes the live registry over the wire via
//! the `{"cmd":"stats"}` protocol command (JSON snapshot, optionally a
//! Prometheus text rendering — see [`report::prometheus`]).
//!
//! Per-feature and per-iteration diagnostics live in [`diag`]: a
//! screening provenance ledger (`--ledger` / `PALLAS_LEDGER=1`)
//! recording one verdict per feature per sweep, and an always-on
//! solver convergence monitor flagging stalls and divergence
//! (`solver.anomalies`). Query them with the `pallas explain`
//! subcommand or the `{"cmd":"diag"}` protocol command. The full
//! operator's guide — every env var, flag, and surface in one place —
//! is `docs/OBSERVABILITY.md`.
//!
//! ## Safety audit
//!
//! `path --audit` (or [`path::runner::PathConfig::audit`]) re-checks
//! every screened-out feature against the KKT inactivity condition
//! `|θᵀf̂ⱼ| ≤ 1` once each step converges
//! ([`screening::variants::audit_screen`]). For the paper's safe rules
//! this must find nothing; any violation increments the
//! `screening.violations` counter, emits an error-level event, and is
//! reported per step (`audit_violations` in the path JSON/stats).
#![allow(clippy::needless_range_loop)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod diag;
pub mod error;
pub mod linalg;
pub mod path;
pub mod report;
pub mod runtime;
pub mod screening;
pub mod solver;
pub mod svm;
pub mod telemetry;
pub mod testkit;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::data::dataset::Dataset;
    pub use crate::data::{csc::CscMatrix, dense::DenseMatrix, FeatureMatrix};
    pub use crate::error::{Error, Result};
    pub use crate::path::runner::{run_path, PathConfig, PathReport};
    pub use crate::screening::rule::{RuleKind, ScreeningRule};
    pub use crate::solver::api::{SolveReport, Solver, SolverKind};
    pub use crate::svm::problem::Problem;
}

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
