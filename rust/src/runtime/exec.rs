//! Typed executions over the PJRT engine.
//!
//! [`screen_all_pjrt`] is the AOT counterpart of
//! [`crate::screening::rule::screen_all`]: same inputs, same decisions —
//! modulo f32, which is why it applies a configurable *keep margin*
//! (keep iff `bound ≥ 1 − margin`), erring on the side of keeping.
//! Integration tests cross-validate the two implementations.

use crate::data::FeatureMatrix;
use crate::error::{Error, Result};
use crate::runtime::engine::{GradExe, PjrtEngine, ScreenExe};
use crate::runtime::literal::{literal_f32, to_f32};
use crate::screening::precompute::SharedContext;
use crate::screening::rule::{RuleKind, ScreenReport};

/// Width of the `[y | 1 | θ₁ | 0…]` panel (mirrors python `V_COLS`).
pub const V_COLS: usize = 8;
/// Length of the shared scalar pack (mirrors python `SHARED_LEN`).
pub const SHARED_LEN: usize = 24;

/// Options for the PJRT screening pass.
#[derive(Debug, Clone, Copy)]
pub struct PjrtScreenOptions {
    /// Keep iff `bound ≥ 1 − keep_margin` — absorbs f32 kernel error.
    /// 1e−3 keeps safety with a negligible loss of screening power.
    pub keep_margin: f64,
}

impl Default for PjrtScreenOptions {
    fn default() -> Self {
        PjrtScreenOptions { keep_margin: 1e-3 }
    }
}

/// Serializes a [`SharedContext`] into the kernel's f32 scalar pack
/// (index layout shared with `python/compile/kernels/screen.py`).
pub fn shared_pack(ctx: &SharedContext) -> [f32; SHARED_LEN] {
    let mut s = [0.0f32; SHARED_LEN];
    s[0] = ctx.inv1 as f32;
    s[1] = ctx.inv2 as f32;
    s[2] = ctx.ysq as f32;
    s[3] = ctx.na as f32;
    s[4] = if ctx.has_a { 1.0 } else { 0.0 };
    s[5] = ctx.a_y as f32;
    s[6] = ctx.a_1 as f32;
    s[7] = ctx.a_t as f32;
    s[8] = ctx.b_y as f32;
    s[9] = ctx.b_sq as f32;
    s[10] = ctx.pya_sq as f32;
    s[11] = ctx.pyb_sq as f32;
    s[12] = ctx.pya_pyb as f32;
    s[13] = ctx.pay_sq as f32;
    s[14] = ctx.pa1_sq as f32;
    s[15] = ctx.pa1_pay as f32;
    s[16] = ctx.ppay_pa1_sq as f32;
    s
}

/// Builds the `(n_pad, V_COLS)` row-major panel.
pub fn build_v_panel(y: &[f64], theta1: &[f64], n_pad: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n_pad * V_COLS];
    for i in 0..y.len() {
        v[i * V_COLS] = y[i] as f32;
        v[i * V_COLS + 1] = 1.0;
        v[i * V_COLS + 2] = theta1[i] as f32;
    }
    v
}

/// Fills one `(block_m, n_pad)` row-major weighted-feature block.
/// Rows past the feature range stay zero (decision-neutral padding).
pub fn fill_xhat_block<X: FeatureMatrix>(
    x: &X,
    y: &[f64],
    j0: usize,
    block_m: usize,
    n_pad: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), block_m * n_pad);
    out.iter_mut().for_each(|v| *v = 0.0);
    let m = x.n_features();
    for jj in 0..block_m {
        let j = j0 + jj;
        if j >= m {
            break;
        }
        let row = &mut out[jj * n_pad..(jj + 1) * n_pad];
        x.col_visit(j, &mut |i, v| {
            row[i] = (v * y[i]) as f32;
        });
    }
}

impl ScreenExe {
    /// Executes the bound kernel for one feature block.
    pub fn run(&self, xhat_block: &[f32], v: &[f32], shared: &[f32]) -> Result<Vec<f32>> {
        let lits = [
            literal_f32(xhat_block, &[self.block_m, self.n])?,
            literal_f32(v, &[self.n, V_COLS])?,
            literal_f32(shared, &[SHARED_LEN])?,
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| Error::runtime(format!("screen execute: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("screen sync: {e}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("screen tuple: {e}")))?;
        to_f32(&out)
    }
}

impl GradExe {
    /// Executes the gradient graph: returns `(grad_w, grad_b, loss)`.
    pub fn run(&self, x: &[f32], y: &[f32], w: &[f32], b: f32) -> Result<(Vec<f32>, f32, f32)> {
        let lits = [
            literal_f32(x, &[self.n, self.m])?,
            literal_f32(y, &[self.n])?,
            literal_f32(w, &[self.m])?,
            literal_f32(&[b], &[1])?,
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| Error::runtime(format!("grad execute: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("grad sync: {e}")))?;
        let parts = result
            .to_tuple()
            .map_err(|e| Error::runtime(format!("grad tuple: {e}")))?;
        if parts.len() != 3 {
            return Err(Error::runtime(format!("grad arity {}", parts.len())));
        }
        let gw = to_f32(&parts[0])?;
        let gb = to_f32(&parts[1])?[0];
        let loss = to_f32(&parts[2])?[0];
        Ok((gw, gb, loss))
    }
}

/// The full screening pass through the PJRT engine — AOT counterpart of
/// [`crate::screening::rule::screen_all`] for the paper rule.
pub fn screen_all_pjrt<X: FeatureMatrix>(
    engine: &PjrtEngine,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2: f64,
    opts: &PjrtScreenOptions,
) -> Result<ScreenReport> {
    let t0 = std::time::Instant::now();
    let n = x.n_samples();
    let m = x.n_features();
    let exe = engine
        .screen_exe_for(n)
        .ok_or_else(|| Error::runtime(format!("no screen artifact covers n={n}")))?;
    let n_pad = exe.n;
    let bm = exe.block_m;

    // Shared scalars in f64 (reusing the native precompute), cast once.
    let ctx = SharedContext::build(y, theta1, lambda1, lambda2)?;
    let shared = shared_pack(&ctx);
    let v = build_v_panel(y, theta1, n_pad);

    let mut keep = vec![true; m];
    let mut bounds = vec![f64::INFINITY; m];
    let threshold = 1.0 - opts.keep_margin;
    let mut block = vec![0.0f32; bm * n_pad];
    let mut j0 = 0;
    while j0 < m {
        fill_xhat_block(x, y, j0, bm, n_pad, &mut block);
        let out = exe.run(&block, &v, &shared)?;
        for jj in 0..bm.min(m - j0) {
            let u = out[jj] as f64;
            bounds[j0 + jj] = u;
            keep[j0 + jj] = u >= threshold;
        }
        j0 += bm;
    }
    Ok(ScreenReport {
        rule: RuleKind::Paper,
        lambda1,
        lambda2,
        keep,
        bounds,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::svm::problem::Problem;

    #[test]
    fn shared_pack_layout() {
        let p = Problem::from_dataset(&SynthSpec::dense(20, 10, 121).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let ctx = SharedContext::build(
            &p.y,
            &theta1,
            p.lambda_max(),
            0.5 * p.lambda_max(),
        )
        .unwrap();
        let s = shared_pack(&ctx);
        assert_eq!(s[0] as f64, ctx.inv1 as f32 as f64);
        assert_eq!(s[1] as f64, ctx.inv2 as f32 as f64);
        assert_eq!(s.len(), SHARED_LEN);
        // padding slots zero
        assert_eq!(s[17], 0.0);
        assert_eq!(s[23], 0.0);
    }

    #[test]
    fn v_panel_layout() {
        let y = vec![1.0, -1.0];
        let t = vec![0.25, 0.5];
        let v = build_v_panel(&y, &t, 4);
        assert_eq!(v.len(), 4 * V_COLS);
        assert_eq!(v[0], 1.0); // y_0
        assert_eq!(v[1], 1.0); // ones
        assert_eq!(v[2], 0.25); // theta_0
        assert_eq!(v[V_COLS], -1.0); // y_1
        // padded rows zero
        assert!(v[2 * V_COLS..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn xhat_block_fill() {
        let ds = SynthSpec::dense(3, 4, 123).generate();
        let mut out = vec![9.0f32; 2 * 5];
        fill_xhat_block(&ds.x, &ds.y, 2, 2, 5, &mut out);
        // row 0 = feature 2 weighted, row 1 = feature 3 weighted
        let mut col = vec![0.0; 3];
        use crate::data::FeatureMatrix;
        ds.x.densify_col(2, &mut col);
        for i in 0..3 {
            assert!((out[i] as f64 - col[i] * ds.y[i]).abs() < 1e-6);
        }
        // padded sample column zero
        assert_eq!(out[3], 0.0);
        assert_eq!(out[4], 0.0);
    }
}
