//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! * [`engine`] — the [`engine::PjrtEngine`]: PJRT CPU client + artifact
//!   registry keyed by compiled shape (discovered from filenames).
//! * [`literal`] — `Literal` ⇄ slice helpers and padding.
//! * [`exec`] — typed executions: the PJRT screening pass
//!   ([`exec::screen_all_pjrt`]) and the gradient step, each
//!   cross-validated against the native rust implementations in
//!   integration tests.
//!
//! Python never runs at serving time: the artifacts are plain HLO text
//! (the interchange format xla_extension 0.5.1 accepts — serialized
//! jax ≥ 0.5 protos are rejected for their 64-bit instruction ids).

pub mod engine;
pub mod exec;
pub mod literal;

pub use engine::PjrtEngine;
pub use exec::{screen_all_pjrt, PjrtScreenOptions};
