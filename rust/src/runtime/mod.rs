//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! * [`engine`] — the `PjrtEngine`: PJRT CPU client + artifact
//!   registry keyed by compiled shape (discovered from filenames).
//! * [`literal`] — `Literal` ⇄ slice helpers and padding.
//! * [`exec`] — typed executions: the PJRT screening pass
//!   (`screen_all_pjrt`) and the gradient step, each
//!   cross-validated against the native rust implementations in
//!   integration tests.
//!
//! Python never runs at serving time: the artifacts are plain HLO text
//! (the interchange format xla_extension 0.5.1 accepts — serialized
//! jax ≥ 0.5 protos are rejected for their 64-bit instruction ids).
//!
//! ## Feature gate
//!
//! The PJRT path needs the `xla` crate (a PJRT C-API binding), which
//! is not part of the std-only default build. It compiles only with
//! `--features pjrt` (plus the vendored `xla` crate wired into
//! `Cargo.toml`). Without the feature this module exposes the same
//! public surface as [`stub`] types whose `load`/`screen_all_pjrt`
//! return [`crate::error::Error::Runtime`] — callers (CLI `--engine
//! pjrt`, benches, tests) degrade gracefully instead of failing to
//! compile.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(feature = "pjrt")]
pub mod literal;

#[cfg(feature = "pjrt")]
pub use engine::PjrtEngine;
#[cfg(feature = "pjrt")]
pub use exec::{screen_all_pjrt, PjrtScreenOptions};

#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(not(feature = "pjrt"))]
pub use stub::{screen_all_pjrt, PjrtEngine, PjrtScreenOptions};
