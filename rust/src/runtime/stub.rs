//! Std-only stand-in for the PJRT runtime (built when the `pjrt`
//! feature is off).
//!
//! Mirrors the public surface of [`super::engine`]/[`super::exec`] so
//! every caller — `svmscreen screen --engine pjrt`, the T4 bench, the
//! `pjrt_compare` example, `rust/tests/runtime.rs` — compiles
//! unchanged. All entry points return
//! [`Error::Runtime`](crate::error::Error::Runtime); artifact-dir
//! discovery still works so guarded call sites (`if dir.exists()`)
//! skip cleanly.

use crate::data::FeatureMatrix;
use crate::error::{Error, Result};
use crate::screening::rule::ScreenReport;
use std::path::{Path, PathBuf};

fn disabled<T>() -> Result<T> {
    Err(Error::runtime(
        "svmscreen was built without the `pjrt` feature; \
         rebuild with `--features pjrt` and the vendored `xla` crate",
    ))
}

/// Stub of a compiled screening executable.
#[derive(Debug, Clone, Copy)]
pub struct ScreenExe {
    /// Compiled sample dimension (padded n).
    pub n: usize,
    /// Compiled feature-block size.
    pub block_m: usize,
}

impl ScreenExe {
    /// Always fails: the binary was built without PJRT support.
    pub fn run(&self, _xhat_block: &[f32], _v: &[f32], _shared: &[f32]) -> Result<Vec<f32>> {
        disabled()
    }
}

/// Stub of a compiled gradient executable.
#[derive(Debug, Clone, Copy)]
pub struct GradExe {
    /// Compiled sample dimension.
    pub n: usize,
    /// Compiled feature dimension.
    pub m: usize,
}

impl GradExe {
    /// Always fails: the binary was built without PJRT support.
    pub fn run(&self, _x: &[f32], _y: &[f32], _w: &[f32], _b: f32) -> Result<(Vec<f32>, f32, f32)> {
        disabled()
    }
}

/// Stub engine: construction always fails with a runtime error.
#[derive(Debug)]
pub struct PjrtEngine {
    /// Where artifacts would have been loaded from.
    pub artifact_dir: PathBuf,
}

impl PjrtEngine {
    /// Always fails: the binary was built without PJRT support.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        disabled()
    }

    /// Default artifact dir relative to the repo root / cwd (same
    /// resolution as the real engine, so existence checks behave
    /// identically).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SVMSCREEN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// No compiled shapes in a stub engine.
    pub fn screen_exe_for(&self, _n: usize) -> Option<&ScreenExe> {
        None
    }

    /// No compiled shapes in a stub engine.
    pub fn grad_exe_for(&self, _n: usize, _m: usize) -> Option<&GradExe> {
        None
    }
}

/// Options for the PJRT screening pass (kept identical to the real
/// implementation so configs round-trip).
#[derive(Debug, Clone, Copy)]
pub struct PjrtScreenOptions {
    /// Keep iff `bound ≥ 1 − keep_margin` — absorbs f32 kernel error.
    pub keep_margin: f64,
}

impl Default for PjrtScreenOptions {
    fn default() -> Self {
        PjrtScreenOptions { keep_margin: 1e-3 }
    }
}

/// Always fails: the binary was built without PJRT support.
pub fn screen_all_pjrt<X: FeatureMatrix>(
    _engine: &PjrtEngine,
    _x: &X,
    _y: &[f64],
    _theta1: &[f64],
    _lambda1: f64,
    _lambda2: f64,
    _opts: &PjrtScreenOptions,
) -> Result<ScreenReport> {
    disabled()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surface_errors_cleanly() {
        let err = PjrtEngine::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        assert!(!PjrtEngine::default_dir().as_os_str().is_empty());
    }
}
