//! The PJRT engine: client + compiled-artifact registry.
//!
//! Artifacts are discovered from `artifacts/` by filename convention
//! (`screen_n{N}_b{B}.hlo.txt`, `grad_n{N}_m{M}.hlo.txt`), compiled once
//! at load, and selected at execution time by "smallest compiled shape
//! that fits" — inputs are zero-padded up to the compiled shape, which
//! the kernels are built to treat as decision-neutral.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A compiled screening executable: bounds for one (block_m, n) block.
pub struct ScreenExe {
    /// Compiled sample dimension (padded n).
    pub n: usize,
    /// Compiled feature-block size.
    pub block_m: usize,
    /// The loaded executable.
    pub exe: xla::PjRtLoadedExecutable,
}

/// A compiled gradient executable for an (n, m) dense problem.
pub struct GradExe {
    /// Compiled sample dimension.
    pub n: usize,
    /// Compiled feature dimension.
    pub m: usize,
    /// The loaded executable.
    pub exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU client plus the artifact registry.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    /// Screening executables keyed by compiled n (ascending).
    pub screen: BTreeMap<usize, ScreenExe>,
    /// Gradient executables keyed by (n, m).
    pub grad: BTreeMap<(usize, usize), GradExe>,
    /// Where the artifacts were loaded from.
    pub artifact_dir: PathBuf,
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine")
            .field("platform", &self.client.platform_name())
            .field("screen_shapes", &self.screen.keys().collect::<Vec<_>>())
            .field("grad_shapes", &self.grad.keys().collect::<Vec<_>>())
            .finish()
    }
}

/// Parses `screen_n{N}_b{B}` / `grad_n{N}_m{M}` stems.
fn parse_stem(stem: &str) -> Option<(&'static str, usize, usize)> {
    let parts: Vec<&str> = stem.split('_').collect();
    if parts.len() != 3 {
        return None;
    }
    let num = |s: &str, prefix: char| -> Option<usize> {
        s.strip_prefix(prefix).and_then(|t| t.parse().ok())
    };
    match parts[0] {
        "screen" => Some(("screen", num(parts[1], 'n')?, num(parts[2], 'b')?)),
        "grad" => Some(("grad", num(parts[1], 'n')?, num(parts[2], 'm')?)),
        _ => None,
    }
}

impl PjrtEngine {
    /// Creates the CPU client and compiles every artifact in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e}")))?;
        let mut engine = PjrtEngine {
            client,
            screen: BTreeMap::new(),
            grad: BTreeMap::new(),
            artifact_dir: dir.to_path_buf(),
        };
        let entries = std::fs::read_dir(dir)
            .map_err(|e| Error::runtime(format!("artifact dir {dir:?}: {e}")))?;
        for entry in entries {
            let path = entry?.path();
            let name = match path.file_name().and_then(|s| s.to_str()) {
                Some(n) if n.ends_with(".hlo.txt") => n,
                _ => continue,
            };
            let stem = name.trim_end_matches(".hlo.txt");
            if let Some((kind, a, b)) = parse_stem(stem) {
                let exe = engine.compile_file(&path)?;
                match kind {
                    "screen" => {
                        engine.screen.insert(a, ScreenExe { n: a, block_m: b, exe });
                    }
                    "grad" => {
                        engine.grad.insert((a, b), GradExe { n: a, m: b, exe });
                    }
                    _ => unreachable!(),
                }
            }
        }
        if engine.screen.is_empty() && engine.grad.is_empty() {
            return Err(Error::runtime(format!(
                "no artifacts found in {dir:?}; run `make artifacts`"
            )));
        }
        Ok(engine)
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {path:?}: {e}")))
    }

    /// The smallest compiled screening shape with `n_compiled >= n`.
    pub fn screen_exe_for(&self, n: usize) -> Option<&ScreenExe> {
        self.screen.range(n..).next().map(|(_, e)| e)
    }

    /// The smallest compiled gradient shape covering `(n, m)`.
    pub fn grad_exe_for(&self, n: usize, m: usize) -> Option<&GradExe> {
        self.grad
            .iter()
            .filter(|((cn, cm), _)| *cn >= n && *cm >= m)
            .min_by_key(|((cn, cm), _)| cn * cm)
            .map(|(_, e)| e)
    }

    /// Default artifact dir relative to the repo root / cwd.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SVMSCREEN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stem_parsing() {
        assert_eq!(parse_stem("screen_n1024_b256"), Some(("screen", 1024, 256)));
        assert_eq!(parse_stem("grad_n256_m512"), Some(("grad", 256, 512)));
        assert_eq!(parse_stem("bogus_n1_b2"), None);
        assert_eq!(parse_stem("screen_x1_b2"), None);
        assert_eq!(parse_stem("screen_n1"), None);
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(PjrtEngine::load("/nonexistent/dir").is_err());
    }

    // Engine-with-artifacts tests live in rust/tests/runtime.rs (they
    // need `make artifacts` to have run).
}
