//! `xla::Literal` ⇄ slice helpers.

use crate::error::{Error, Result};

/// Builds an f32 literal of the given dims from a row-major slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let expect: usize = dims.iter().product();
    if data.len() != expect {
        return Err(Error::runtime(format!(
            "literal payload {} != shape product {expect}",
            data.len()
        )));
    }
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    lit.reshape(&dims_i64)
        .map_err(|e| Error::runtime(format!("reshape: {e}")))
}

/// Extracts a literal into `Vec<f32>`.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| Error::runtime(format!("to_vec: {e}")))
}

/// Copies `src` (len ≤ pad_len) into a zero-padded vector of `pad_len`.
pub fn pad_f32(src: &[f64], pad_len: usize) -> Vec<f32> {
    assert!(src.len() <= pad_len);
    let mut out = vec![0.0f32; pad_len];
    for (o, s) in out.iter_mut().zip(src) {
        *o = *s as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_copies_and_zeros() {
        let p = pad_f32(&[1.0, 2.0], 4);
        assert_eq!(p, vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn literal_shape_validated() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
