//! λ-grid construction.
//!
//! Both constructors return [`Result`] instead of asserting: a grid is
//! built from *data-derived* quantities (`lambda_max` of whatever the
//! user loaded), so degenerate inputs are runtime conditions to report,
//! not programmer errors to panic on. `count == 0` is documented as the
//! empty grid, not an error — "no path points" is a valid request.

use crate::error::{Error, Result};

/// Geometric grid of `count` values from `lambda_max` down to
/// `min_frac * lambda_max` (exclusive of `lambda_max` itself, inclusive
/// of the endpoint), descending — the standard path grid.
///
/// Errors on non-finite or non-positive `lambda_max` (degenerate data:
/// all-zero features, NaN labels) and on `min_frac` outside `(0, 1)`
/// (the grid would ascend or repeat `lambda_max`). `count == 0` returns
/// an empty grid.
pub fn geometric(lambda_max: f64, min_frac: f64, count: usize) -> Result<Vec<f64>> {
    if !(lambda_max.is_finite() && lambda_max > 0.0) {
        return Err(Error::data(format!(
            "grid needs positive finite lambda_max, got {lambda_max}"
        )));
    }
    if !(min_frac.is_finite() && min_frac > 0.0 && min_frac < 1.0) {
        return Err(Error::config(format!(
            "grid min_frac must be in (0, 1), got {min_frac}"
        )));
    }
    if count == 0 {
        return Ok(Vec::new());
    }
    let ratio = min_frac.powf(1.0 / count as f64);
    Ok((1..=count).map(|k| lambda_max * ratio.powi(k as i32)).collect())
}

/// Linear grid (used by gap-sweep experiments), `lambda_hi` down to
/// `lambda_lo` inclusive.
///
/// Errors unless `lambda_hi > lambda_lo > 0` and both are finite.
/// `count == 0` returns an empty grid; `count == 1` returns just
/// `lambda_hi`.
pub fn linear(lambda_hi: f64, lambda_lo: f64, count: usize) -> Result<Vec<f64>> {
    if !(lambda_hi.is_finite() && lambda_lo.is_finite() && lambda_hi > lambda_lo && lambda_lo > 0.0)
    {
        return Err(Error::config(format!(
            "linear grid needs lambda_hi > lambda_lo > 0 (finite), got hi={lambda_hi} lo={lambda_lo}"
        )));
    }
    match count {
        0 => Ok(Vec::new()),
        1 => Ok(vec![lambda_hi]),
        _ => {
            let step = (lambda_hi - lambda_lo) / (count - 1) as f64;
            Ok((0..count).map(|k| lambda_hi - step * k as f64).collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn geometric_endpoints_and_order() {
        let g = geometric(10.0, 0.01, 20).unwrap();
        assert_eq!(g.len(), 20);
        assert!(g[0] < 10.0);
        assert_close(g[19], 0.1, 1e-9, "endpoint");
        for k in 1..20 {
            assert!(g[k] < g[k - 1], "descending");
            // constant ratio
            assert_close(g[k] / g[k - 1], g[1] / g[0], 1e-9, "ratio");
        }
    }

    #[test]
    fn linear_grid() {
        let g = linear(5.0, 1.0, 5).unwrap();
        assert_eq!(g, vec![5.0, 4.0, 3.0, 2.0, 1.0]);
        assert_eq!(linear(5.0, 1.0, 0).unwrap(), Vec::<f64>::new());
        assert_eq!(linear(5.0, 1.0, 1).unwrap(), vec![5.0]);
        assert!(linear(1.0, 5.0, 3).is_err());
        assert!(linear(5.0, 0.0, 3).is_err());
    }

    #[test]
    fn geometric_rejects_degenerate_inputs() {
        // Every former assert!/silent-misbehavior case is now an Err or
        // a documented empty grid.
        assert!(geometric(10.0, 1.5, 5).is_err(), "min_frac >= 1");
        assert!(geometric(10.0, 1.0, 5).is_err(), "min_frac == 1");
        assert!(geometric(10.0, 0.0, 5).is_err(), "min_frac == 0");
        assert!(geometric(0.0, 0.5, 5).is_err(), "lambda_max == 0");
        assert!(geometric(-3.0, 0.5, 5).is_err(), "negative lambda_max");
        assert!(geometric(f64::NAN, 0.5, 5).is_err(), "NaN lambda_max");
        assert!(geometric(f64::INFINITY, 0.5, 5).is_err(), "inf lambda_max");
        assert!(geometric(10.0, f64::NAN, 5).is_err(), "NaN min_frac");
        assert_eq!(geometric(10.0, 0.5, 0).unwrap(), Vec::<f64>::new());
    }
}
