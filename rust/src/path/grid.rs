//! λ-grid construction.

/// Geometric grid of `count` values from `lambda_max` down to
/// `min_frac * lambda_max` (exclusive of `lambda_max` itself, inclusive
/// of the endpoint), descending — the standard path grid.
pub fn geometric(lambda_max: f64, min_frac: f64, count: usize) -> Vec<f64> {
    assert!(lambda_max > 0.0, "lambda_max must be positive");
    assert!((0.0..1.0).contains(&min_frac) && min_frac > 0.0, "min_frac in (0,1)");
    assert!(count >= 1);
    let ratio = min_frac.powf(1.0 / count as f64);
    (1..=count).map(|k| lambda_max * ratio.powi(k as i32)).collect()
}

/// Linear grid (used by gap-sweep experiments).
pub fn linear(lambda_hi: f64, lambda_lo: f64, count: usize) -> Vec<f64> {
    assert!(lambda_hi > lambda_lo && lambda_lo > 0.0);
    assert!(count >= 2);
    let step = (lambda_hi - lambda_lo) / (count - 1) as f64;
    (0..count).map(|k| lambda_hi - step * k as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn geometric_endpoints_and_order() {
        let g = geometric(10.0, 0.01, 20);
        assert_eq!(g.len(), 20);
        assert!(g[0] < 10.0);
        assert_close(g[19], 0.1, 1e-9, "endpoint");
        for k in 1..20 {
            assert!(g[k] < g[k - 1], "descending");
            // constant ratio
            assert_close(g[k] / g[k - 1], g[1] / g[0], 1e-9, "ratio");
        }
    }

    #[test]
    fn linear_grid() {
        let g = linear(5.0, 1.0, 5);
        assert_eq!(g, vec![5.0, 4.0, 3.0, 2.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn geometric_validates() {
        geometric(10.0, 1.5, 5);
    }
}
