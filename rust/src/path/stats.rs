//! Per-step path records.

use crate::coordinator::protocol::Json;
use crate::screening::RuleKind;
use crate::telemetry::{self, Level};

/// One λ-step of a path run.
#[derive(Debug, Clone)]
pub struct PathStep {
    /// The λ solved at this step.
    pub lambda: f64,
    /// `λ / λ_max`.
    pub lambda_frac: f64,
    /// Features kept by screening (solver input size).
    pub kept: usize,
    /// Features screened out.
    pub screened: usize,
    /// Rejection ratio `screened / m`.
    pub rejection: f64,
    /// Non-zeros in the solution.
    pub nnz: usize,
    /// Solver iterations.
    pub iterations: usize,
    /// Relative duality gap achieved.
    pub rel_gap: f64,
    /// Seconds spent screening.
    pub screen_seconds: f64,
    /// Seconds spent solving.
    pub solve_seconds: f64,
    /// Violations repaired at this step (unsafe rules only).
    pub violations: usize,
    /// KKT violations found by the safety audit (`None` when the audit
    /// did not run; `Some(0)` is a clean audited step).
    pub audit_violations: Option<usize>,
    /// Near-miss features: screening bounds within the configured
    /// epsilon of the keep threshold ([`crate::diag::ledger`]).
    pub near_miss: usize,
    /// Solver convergence anomalies flagged at this step
    /// ([`crate::diag::convergence`]).
    pub anomalies: usize,
}

impl PathStep {
    /// Header row matching [`PathStep::row`].
    pub fn header() -> [&'static str; 11] {
        [
            "lambda/lmax",
            "kept",
            "screened",
            "reject%",
            "nmiss",
            "nnz",
            "iters",
            "anom",
            "rel_gap",
            "screen_s",
            "solve_s",
        ]
    }

    /// The step as a JSON object (JSONL traces, `stats` payloads).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lambda", Json::Num(self.lambda)),
            ("lambda_frac", Json::Num(self.lambda_frac)),
            ("kept", Json::Num(self.kept as f64)),
            ("screened", Json::Num(self.screened as f64)),
            ("rejection", Json::Num(self.rejection)),
            ("nnz", Json::Num(self.nnz as f64)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("rel_gap", Json::Num(self.rel_gap)),
            ("screen_seconds", Json::Num(self.screen_seconds)),
            ("solve_seconds", Json::Num(self.solve_seconds)),
            ("violations", Json::Num(self.violations as f64)),
            (
                "audit_violations",
                match self.audit_violations {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ),
            ("near_miss", Json::Num(self.near_miss as f64)),
            ("anomalies", Json::Num(self.anomalies as f64)),
        ])
    }

    /// Reports this step into the telemetry layer: aggregate counters
    /// plus one structured `path.step` event (the JSONL sink records
    /// the full record; stderr gets a one-liner at debug level).
    pub fn emit(&self) {
        let tele = telemetry::global();
        tele.counter("path.steps").inc();
        tele.counter("path.features_screened").add(self.screened as u64);
        tele.counter("path.features_kept").add(self.kept as u64);
        tele.counter("path.violations").add(self.violations as u64);
        tele.counter("path.near_miss").add(self.near_miss as u64);
        tele.counter("path.anomalies").add(self.anomalies as u64);
        if let Some(n) = self.audit_violations {
            tele.counter("path.audit_steps").inc();
            tele.counter("path.audit_violations").add(n as u64);
        }
        tele.gauge("path.last_rejection").set(self.rejection);
        if telemetry::enabled(Level::Debug) {
            telemetry::emit_with(
                Level::Debug,
                "path.step",
                &format!(
                    "lambda/lmax {:.4}: kept {} screened {} nnz {} \
                     ({} iters, rel_gap {:.2e}, screen {:.4}s solve {:.4}s)",
                    self.lambda_frac,
                    self.kept,
                    self.screened,
                    self.nnz,
                    self.iterations,
                    self.rel_gap,
                    self.screen_seconds,
                    self.solve_seconds
                ),
                Some(&self.to_json()),
            );
        }
    }

    /// A table row for reports.
    pub fn row(&self) -> [String; 11] {
        [
            format!("{:.4}", self.lambda_frac),
            self.kept.to_string(),
            self.screened.to_string(),
            format!("{:.1}", 100.0 * self.rejection),
            self.near_miss.to_string(),
            self.nnz.to_string(),
            self.iterations.to_string(),
            self.anomalies.to_string(),
            format!("{:.2e}", self.rel_gap),
            format!("{:.4}", self.screen_seconds),
            format!("{:.4}", self.solve_seconds),
        ]
    }
}

/// Aggregates over a whole path run.
#[derive(Debug, Clone, Copy, Default)]
pub struct PathTotals {
    /// Total screening seconds.
    pub screen_seconds: f64,
    /// Total solve seconds.
    pub solve_seconds: f64,
    /// Mean rejection ratio.
    pub mean_rejection: f64,
    /// Total violations repaired (unsafe rules).
    pub violations: usize,
    /// Total near-miss features across steps.
    pub near_miss: usize,
    /// Total solver anomalies across steps.
    pub anomalies: usize,
}

/// Computes totals from steps.
pub fn totals(steps: &[PathStep]) -> PathTotals {
    let mut t = PathTotals::default();
    for s in steps {
        t.screen_seconds += s.screen_seconds;
        t.solve_seconds += s.solve_seconds;
        t.mean_rejection += s.rejection;
        t.violations += s.violations;
        t.near_miss += s.near_miss;
        t.anomalies += s.anomalies;
    }
    if !steps.is_empty() {
        t.mean_rejection /= steps.len() as f64;
    }
    t
}

/// Human tag for a (rule, solver) configuration.
pub fn config_tag(rule: RuleKind, solver: crate::solver::SolverKind) -> String {
    format!("{}+{}", rule.name(), solver.name())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(rej: f64, ss: f64, vs: usize) -> PathStep {
        PathStep {
            lambda: 1.0,
            lambda_frac: 0.5,
            kept: 10,
            screened: 90,
            rejection: rej,
            nnz: 5,
            iterations: 7,
            rel_gap: 1e-7,
            screen_seconds: ss,
            solve_seconds: 2.0 * ss,
            violations: vs,
            audit_violations: None,
            near_miss: 3,
            anomalies: 1,
        }
    }

    #[test]
    fn totals_aggregate() {
        let t = totals(&[step(0.2, 1.0, 1), step(0.4, 2.0, 2)]);
        assert_eq!(t.screen_seconds, 3.0);
        assert_eq!(t.solve_seconds, 6.0);
        assert!((t.mean_rejection - 0.3).abs() < 1e-12);
        assert_eq!(t.violations, 3);
        assert_eq!(t.near_miss, 6);
        assert_eq!(t.anomalies, 2);
    }

    #[test]
    fn to_json_and_emit_report_all_fields() {
        let s = step(0.9, 0.1, 2);
        let json = s.to_json().encode();
        for key in ["lambda", "kept", "screened", "nnz", "rel_gap", "violations"] {
            assert!(json.contains(&format!("\"{key}\"")), "{json}");
        }
        let before = crate::telemetry::global().snapshot();
        s.emit();
        let after = crate::telemetry::global().snapshot();
        assert_eq!(
            after.counters["path.steps"],
            before.counters.get("path.steps").copied().unwrap_or(0) + 1
        );
        assert_eq!(
            after.counters["path.violations"],
            before.counters.get("path.violations").copied().unwrap_or(0) + 2
        );
    }

    #[test]
    fn row_and_header_align() {
        let s = step(0.9, 0.1, 0);
        assert_eq!(PathStep::header().len(), s.row().len());
        assert_eq!(
            config_tag(RuleKind::Paper, crate::solver::SolverKind::Cd),
            "paper+cd"
        );
    }
}
