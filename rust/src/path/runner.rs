//! The sequential screening path runner.
//!
//! For a descending λ-grid below `λ_max`, each step:
//!
//! 1. **screen** the features for λ_k using the previous solved dual
//!    point `(λ_{k−1}, θ_{k−1})` (the first step uses the closed-form
//!    point at `λ_max`, footnote 1 of the paper);
//! 2. **solve** the reduced problem over the kept features, warm-started
//!    from the previous solution;
//! 3. for **unsafe** rules (strong), verify the discarded features via
//!    the KKT condition |θᵀf̂| ≤ 1 and re-solve with the violators added
//!    back (the standard strong-rule repair loop);
//! 4. map the solution to the dual via Eq. (20) for the next step.
//!
//! ### Approximation caveat (documented, measured in T2)
//!
//! The rule's derivation assumes `θ₁` is the *exact* dual optimum. We
//! terminate solves at a certified duality gap ≤ `solve.tol`, so `θ₁`
//! carries an O(√gap) error. With the default `tol = 1e−6` (and `1e−9`
//! for safety audits) no violation was ever observed; T2 quantifies this.

use crate::coordinator::parallel::screen_all_parallel_with;
use crate::data::FeatureMatrix;
use crate::error::Result;
use crate::path::stats::{totals, PathStep, PathTotals};
use crate::report::table::Table;
use crate::screening::rule::RuleKind;
use crate::solver::api::{SolveOptions, SolverKind};
use crate::solver::reduced::ReducedProblem;
use crate::svm::problem::Problem;
use crate::telemetry::Span;

/// Path-runner configuration.
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// Screening rule applied between steps.
    pub rule: RuleKind,
    /// Solver used for the reduced problems.
    pub solver: SolverKind,
    /// Per-step solver options.
    pub solve: SolveOptions,
    /// Tolerance for the unsafe-rule violation check (|θᵀf̂| > 1 + tol).
    pub violation_tol: f64,
    /// Safety-audit mode: after each step converges, re-check every
    /// screened-out feature against the KKT condition at the solution
    /// ([`crate::screening::variants::audit_screen`]). Violations land
    /// in `screening.violations` and each emits an error event.
    pub audit: bool,
    /// Worker threads for the screening sweeps and column gathers
    /// (1 = sequential; results are bit-identical either way).
    pub workers: usize,
    /// Reuse the previous step's reduced matrix when the kept set is a
    /// subset of the previous one; reuse efficacy is metered as
    /// `path.cache.hits` / `path.cache.misses`. Disable only to test
    /// equivalence against from-scratch gathers.
    pub incremental: bool,
    /// Near-miss epsilon: a feature whose screening bound lands within
    /// this distance of the keep threshold counts toward the step's
    /// `near_miss` field ([`crate::diag::ledger::near_miss_count`]).
    pub near_miss_eps: f64,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            rule: RuleKind::Paper,
            solver: SolverKind::Cd,
            solve: SolveOptions::default(),
            violation_tol: 1e-4,
            audit: false,
            workers: crate::coordinator::pool::default_workers(),
            incremental: true,
            near_miss_eps: crate::diag::ledger::DEFAULT_NEAR_MISS_EPS,
        }
    }
}

/// Full record of a path run.
#[derive(Debug, Clone)]
pub struct PathReport {
    /// Problem name.
    pub problem: String,
    /// Configuration used.
    pub rule: RuleKind,
    /// Solver used.
    pub solver: SolverKind,
    /// Per-step records (in grid order).
    pub steps: Vec<PathStep>,
    /// The solutions' weight vectors per step.
    pub weights: Vec<Vec<f64>>,
    /// Bias per step.
    pub biases: Vec<f64>,
    /// Total wall-clock seconds.
    pub total_seconds: f64,
}

impl PathReport {
    /// Aggregated totals.
    pub fn totals(&self) -> PathTotals {
        totals(&self.steps)
    }

    /// A human-readable per-step table.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "path {} rule={} solver={} ({} steps, {:.3}s)",
                self.problem,
                self.rule.name(),
                self.solver.name(),
                self.steps.len(),
                self.total_seconds
            ),
            &PathStep::header(),
        );
        for s in &self.steps {
            t.row(&s.row());
        }
        t
    }
}

/// Runs the sequential-screening path. `grid` must be descending and
/// strictly below `problem.lambda_max()`.
pub fn run_path(problem: &Problem, grid: &[f64], cfg: &PathConfig) -> Result<PathReport> {
    // Span (not a raw Instant): the run's wall time lands in the
    // `path.run.seconds` histogram and the debug trace for free.
    let run_span = Span::enter_labeled(
        "path.run",
        Some(format!(
            "{} rule={} solver={} steps={}",
            problem.name,
            cfg.rule.name(),
            cfg.solver.name(),
            grid.len()
        )),
    );
    let m = problem.m();
    let lmax = problem.lambda_max();

    // Path-wide feature cache: one O(nnz) pass, then every screening
    // sweep is a single θ-dot per feature and every CD solve gets its
    // curvature for free.
    let cache = problem.cache();
    // Reduced-matrix reuse metrics, registered up front so they show as
    // zeros in stats snapshots even before the first reduced step.
    let tele = crate::telemetry::global();
    let cache_hits = tele.counter("path.cache.hits");
    let cache_misses = tele.counter("path.cache.misses");
    let gather_bytes = tele.counter("path.gather_bytes");
    let gather_seconds = tele.histogram("path.step.gather_seconds");

    // Previous solved point: closed form at lambda_max.
    let mut lambda_prev = lmax;
    let mut theta_prev = problem.theta_at_lambda_max().theta();
    let mut w_prev = vec![0.0; m];
    // Previous step's reduced problem (incremental gather source).
    let mut prev_red: Option<ReducedProblem> = None;

    let mut steps = Vec::with_capacity(grid.len());
    let mut weights = Vec::with_capacity(grid.len());
    let mut biases = Vec::with_capacity(grid.len());

    for &lambda in grid {
        if !(lambda < lambda_prev || (lambda < lmax && lambda > 0.0)) {
            return Err(crate::error::Error::screening(format!(
                "grid must descend below lambda_max: {lambda} vs prev {lambda_prev}"
            )));
        }
        // 1. Screen (lambda_prev, theta_prev) -> lambda: block-parallel
        // executor with the cached λ-independent stats.
        let screen_span = Span::enter("path.screen");
        let screen = screen_all_parallel_with(
            cfg.rule,
            &problem.x,
            &problem.y,
            &theta_prev,
            lambda_prev,
            lambda,
            cfg.workers,
            Some(cache),
        )?;
        let mut kept = screen.kept_indices();
        let screen_seconds = screen.seconds;
        // Per-step bound-tightness summary; cheap (one pass over the
        // bounds), so it reports even when the full ledger is off.
        let near_miss =
            crate::diag::ledger::near_miss_count(&screen.bounds, cfg.near_miss_eps);
        drop(screen_span);

        // 2. Reduced solve with warm start.
        let solve_span = Span::enter_labeled("path.solve", Some(format!("lambda {lambda:.4e}")));
        let mut violations = 0usize;
        let (w, b, iterations, rel_gap, anomalies) = loop {
            let rep = if kept.len() == m {
                crate::solver::api::solve_with_curvature(
                    cfg.solver,
                    &problem.x,
                    &problem.y,
                    lambda,
                    Some(&w_prev),
                    &cfg.solve,
                    Some(&cache.norm_sq),
                )?
            } else {
                let t_gather = std::time::Instant::now();
                let (red, reused) = match prev_red.as_ref().filter(|_| cfg.incremental) {
                    Some(prev) => ReducedProblem::build_incremental(
                        prev,
                        &problem.x,
                        kept.clone(),
                        Some(cache),
                        cfg.workers,
                    )?,
                    None => (
                        ReducedProblem::build_with(
                            &problem.x,
                            kept.clone(),
                            Some(cache),
                            cfg.workers,
                        )?,
                        false,
                    ),
                };
                gather_seconds.record(t_gather.elapsed().as_secs_f64());
                if reused {
                    cache_hits.inc();
                } else {
                    cache_misses.inc();
                }
                gather_bytes.add(red.gathered_bytes());
                let rep =
                    red.solve(cfg.solver, &problem.y, lambda, Some(&w_prev), &cfg.solve)?;
                prev_red = Some(red);
                rep
            };

            // 3. Unsafe-rule repair loop: verify discarded features.
            if cfg.rule.is_safe() {
                break (rep.w, rep.b, rep.iterations, rep.gap.rel_gap, rep.anomalies);
            }
            let theta = crate::svm::dual::theta_from_primal(
                &problem.x,
                &problem.y,
                &rep.w,
                rep.b,
                lambda,
            );
            let ytheta: Vec<f64> =
                problem.y.iter().zip(&theta).map(|(a, b)| a * b).collect();
            let kept_set: std::collections::HashSet<usize> =
                kept.iter().copied().collect();
            let mut violators: Vec<usize> = (0..m)
                .filter(|j| !kept_set.contains(j))
                .filter(|&j| problem.x.col_dot(j, &ytheta).abs() > 1.0 + cfg.violation_tol)
                .collect();
            if violators.is_empty() {
                break (rep.w, rep.b, rep.iterations, rep.gap.rel_gap, rep.anomalies);
            }
            violations += violators.len();
            kept.append(&mut violators);
            kept.sort_unstable();
        };
        let solve_seconds = solve_span.finish();
        if violations > 0 {
            crate::tele_warn!(
                "path",
                "unsafe rule {} repaired {violations} violation(s) at lambda {lambda:.4e}",
                cfg.rule.name()
            );
        }

        // 3b. Safety audit: re-check the discarded features against the
        // KKT condition at the converged solution. For safe rules this
        // must come up empty; the counter/event trail is the point.
        let audit_violations = if cfg.audit {
            let audit_span = Span::enter("path.audit");
            let audit = crate::screening::variants::audit_screen(
                &problem.x,
                &problem.y,
                &screen,
                &w,
                b,
                cfg.violation_tol,
            );
            drop(audit_span);
            Some(audit.violations.len())
        } else {
            None
        };

        // 4. Dual map for the next step.
        theta_prev = crate::svm::dual::theta_from_primal(&problem.x, &problem.y, &w, b, lambda);
        lambda_prev = lambda;

        let nnz = w.iter().filter(|v| **v != 0.0).count();
        let step = PathStep {
            lambda,
            lambda_frac: lambda / lmax,
            kept: kept.len(),
            screened: m - kept.len(),
            rejection: (m - kept.len()) as f64 / m as f64,
            nnz,
            iterations,
            rel_gap,
            screen_seconds,
            solve_seconds,
            violations,
            audit_violations,
            near_miss,
            anomalies,
        };
        step.emit();
        steps.push(step);
        w_prev = w.clone();
        weights.push(w);
        biases.push(b);
    }

    Ok(PathReport {
        problem: problem.name.clone(),
        rule: cfg.rule,
        solver: cfg.solver,
        steps,
        weights,
        biases,
        total_seconds: run_span.finish(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::path::grid::geometric;
    use crate::testkit::assert_close;

    fn problem(seed: u64) -> Problem {
        Problem::from_dataset(&SynthSpec::text(60, 150, seed).generate())
    }

    #[test]
    fn screened_path_matches_unscreened_path() {
        // THE correctness property of the whole system: safe screening
        // must not change the solution path (same objectives per step).
        let p = problem(111);
        let grid = geometric(p.lambda_max(), 0.1, 8).unwrap();
        let precise = SolveOptions { tol: 1e-8, max_iter: 20000, ..Default::default() };
        let none = run_path(
            &p,
            &grid,
            &PathConfig { rule: RuleKind::None, solve: precise, ..Default::default() },
        )
        .unwrap();
        let paper = run_path(
            &p,
            &grid,
            &PathConfig { rule: RuleKind::Paper, solve: precise, ..Default::default() },
        )
        .unwrap();
        assert_eq!(none.steps.len(), paper.steps.len());
        for k in 0..grid.len() {
            let obj_none = crate::svm::objective::primal_objective(
                &p.x, &p.y, &none.weights[k], none.biases[k], grid[k],
            );
            let obj_paper = crate::svm::objective::primal_objective(
                &p.x, &p.y, &paper.weights[k], paper.biases[k], grid[k],
            );
            assert_close(obj_paper, obj_none, 1e-5, &format!("objective step {k}"));
            // screening must never discard a feature active in the
            // unscreened solution
            for j in 0..p.m() {
                if none.weights[k][j].abs() > 1e-6 {
                    assert!(
                        paper.steps[k].kept > 0,
                        "sanity: kept set nonempty"
                    );
                }
            }
        }
        // and screening actually did something
        assert!(paper.totals().mean_rejection > 0.1, "{}", paper.totals().mean_rejection);
    }

    #[test]
    fn rejection_decreases_along_path() {
        // As lambda shrinks, more features become active -> rejection drops.
        let p = problem(113);
        let grid = geometric(p.lambda_max(), 0.05, 10).unwrap();
        let rep = run_path(&p, &grid, &PathConfig::default()).unwrap();
        let first = rep.steps.first().unwrap().rejection;
        let last = rep.steps.last().unwrap().rejection;
        assert!(first > last, "rejection {first} -> {last}");
        assert!(first >= 0.5, "near lambda_max rejection should be high: {first}");
    }

    #[test]
    fn strong_rule_repair_loop_runs() {
        let p = problem(115);
        let grid = geometric(p.lambda_max(), 0.1, 6).unwrap();
        let rep = run_path(
            &p,
            &grid,
            &PathConfig { rule: RuleKind::Strong, ..Default::default() },
        )
        .unwrap();
        // The repair loop guarantees correctness even if violations occur;
        // verify final solutions match the unscreened objective.
        let none = run_path(
            &p,
            &grid,
            &PathConfig { rule: RuleKind::None, ..Default::default() },
        )
        .unwrap();
        for k in 0..grid.len() {
            let o1 = crate::svm::objective::primal_objective(
                &p.x, &p.y, &rep.weights[k], rep.biases[k], grid[k],
            );
            let o2 = crate::svm::objective::primal_objective(
                &p.x, &p.y, &none.weights[k], none.biases[k], grid[k],
            );
            assert_close(o1, o2, 1e-4, &format!("strong-rule objective step {k}"));
        }
    }

    #[test]
    fn audit_mode_reports_clean_steps_for_safe_rule() {
        let p = problem(121);
        let grid = geometric(p.lambda_max(), 0.1, 5).unwrap();
        let rep = run_path(
            &p,
            &grid,
            &PathConfig { audit: true, ..Default::default() },
        )
        .unwrap();
        for (k, s) in rep.steps.iter().enumerate() {
            assert_eq!(
                s.audit_violations,
                Some(0),
                "safe rule must audit clean at step {k}"
            );
        }
        // Audit disabled -> the field stays None.
        let plain = run_path(&p, &grid[..2], &PathConfig::default()).unwrap();
        assert!(plain.steps.iter().all(|s| s.audit_violations.is_none()));
    }

    #[test]
    fn summary_table_renders() {
        let p = problem(117);
        let grid = geometric(p.lambda_max(), 0.3, 3).unwrap();
        let rep = run_path(&p, &grid, &PathConfig::default()).unwrap();
        let table = rep.summary_table().to_string();
        assert!(table.contains("paper"));
        assert!(rep.totals().screen_seconds >= 0.0);
        assert_eq!(rep.weights.len(), 3);
    }

    #[test]
    fn rejects_bad_grid() {
        let p = problem(119);
        let bad = vec![p.lambda_max() * 1.1];
        assert!(run_path(&p, &bad, &PathConfig::default()).is_err());
    }
}
