//! Regularization-path training with sequential safe screening — the
//! workflow the paper's rule exists to accelerate.
//!
//! * [`grid`] — geometric λ-grids below `λ_max`.
//! * [`runner`] — the sequential loop: screen(λ_{k−1} → λ_k) → reduced
//!   solve (warm-started) → map to the dual → next step.
//! * [`stats`] — per-step records and report tables.
//!
//! Each run builds the problem's [`crate::data::cache::FeatureCache`]
//! once (per-column `fᵀy`, `fᵀ1`, `‖f‖²`, nnz in one O(nnz) pass),
//! screens with the block-parallel executor
//! ([`runner::PathConfig::workers`]), and *remaps* the cache onto each
//! reduced problem instead of recomputing it. When a step's kept set is
//! a subset of the previous one, the reduced matrix is sub-selected
//! from the previous *reduced* matrix rather than re-gathered from the
//! full one; reuse efficacy is metered as `path.cache.hits` /
//! `path.cache.misses` / `path.gather_bytes` plus the
//! `path.step.gather_seconds` histogram. All reuse paths are
//! bit-identical to the from-scratch gather (`incremental: false`).

pub mod grid;
pub mod runner;
pub mod stats;

pub use grid::geometric;
pub use runner::{run_path, PathConfig, PathReport};
pub use stats::PathStep;
