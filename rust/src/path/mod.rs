//! Regularization-path training with sequential safe screening — the
//! workflow the paper's rule exists to accelerate.
//!
//! * [`grid`] — geometric λ-grids below `λ_max`.
//! * [`runner`] — the sequential loop: screen(λ_{k−1} → λ_k) → reduced
//!   solve (warm-started) → map to the dual → next step.
//! * [`stats`] — per-step records and report tables.

pub mod grid;
pub mod runner;
pub mod stats;

pub use grid::geometric;
pub use runner::{run_path, PathConfig, PathReport};
pub use stats::PathStep;
