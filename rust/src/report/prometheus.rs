//! Prometheus text-exposition rendering of a telemetry snapshot.
//!
//! No `prometheus` crate in the vendored set, so this emits the plain
//! text format by hand: counters as `_total`, gauges as-is, histogram
//! summaries as `<name>{quantile="…"}` summary series plus `_sum` /
//! `_count`. Metric names are sanitized (`.`/`-` → `_`) to match the
//! Prometheus grammar. The server returns this rendering from
//! `{"cmd":"stats","prometheus":true}` so any scraper-shaped tool can
//! consume the live registry.

use crate::telemetry::MetricsSnapshot;
use std::fmt::Write as _;

/// Maps a dotted metric name to a legal Prometheus name.
fn sanitize(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Formats a sample value (Prometheus spells non-finite values `NaN`).
fn val(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "NaN".into()
    }
}

/// Renders the snapshot in Prometheus text exposition format.
pub fn render(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = sanitize(name);
        let _ = writeln!(out, "# TYPE {n}_total counter");
        let _ = writeln!(out, "{n}_total {v}");
    }
    for (name, v) in &snap.gauges {
        let n = sanitize(name);
        let _ = writeln!(out, "# TYPE {n} gauge");
        let _ = writeln!(out, "{n} {}", val(*v));
    }
    for (name, h) in &snap.histograms {
        let n = sanitize(name);
        let _ = writeln!(out, "# TYPE {n} summary");
        for (q, v) in [("0.5", h.p50), ("0.9", h.p90), ("0.99", h.p99)] {
            let _ = writeln!(out, "{n}{{quantile=\"{q}\"}} {}", val(v));
        }
        let _ = writeln!(out, "{n}_sum {}", val(h.sum));
        let _ = writeln!(out, "{n}_count {}", h.count);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    #[test]
    fn sanitize_names() {
        assert_eq!(sanitize("server.screen.seconds"), "server_screen_seconds");
        assert_eq!(sanitize("path-steps"), "path_steps");
        assert_eq!(sanitize("9lives"), "_9lives");
    }

    #[test]
    fn renders_all_metric_kinds() {
        let r = Registry::new();
        r.counter("a.requests").add(7);
        r.gauge("b.lambda").set(0.25);
        for _ in 0..4 {
            r.histogram("c.seconds").record(1e-3);
        }
        let text = render(&r.snapshot());
        assert!(text.contains("# TYPE a_requests_total counter"), "{text}");
        assert!(text.contains("a_requests_total 7"), "{text}");
        assert!(text.contains("b_lambda 0.25"), "{text}");
        assert!(text.contains("c_seconds{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("c_seconds_count 4"), "{text}");
        // every non-comment line is "name value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "{line}");
        }
    }

    #[test]
    fn empty_histogram_renders_nan_not_panic() {
        let r = Registry::new();
        let _ = r.histogram("empty.seconds");
        let text = render(&r.snapshot());
        assert!(text.contains("empty_seconds_count 0"), "{text}");
        assert!(text.contains("NaN"), "{text}");
    }

    #[test]
    fn empty_registry_renders_empty_document() {
        let r = Registry::new();
        assert!(render(&r.snapshot()).is_empty());
    }

    #[test]
    fn non_finite_gauges_render_as_nan_samples() {
        let r = Registry::new();
        r.gauge("g.nan").set(f64::NAN);
        r.gauge("g.inf").set(f64::INFINITY);
        let text = render(&r.snapshot());
        assert!(text.contains("g_nan NaN"), "{text}");
        assert!(text.contains("g_inf NaN"), "{text}");
        // Still "name value" shaped — a scraper can parse every line.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "{line}");
        }
    }
}
