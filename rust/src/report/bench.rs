//! Standardized machine-readable bench artifacts.
//!
//! Every bench in `benches/` used to print tables plus ad-hoc CSV; T1
//! additionally dumped a raw telemetry snapshot. This module gives all
//! of them one schema (`pallas.bench.v1`) so CI can archive and diff
//! runs: a `BENCH_<id>.json` file with the config tag, wall time, the
//! headline screening numbers (mean rejection ratio, speedup over the
//! no-screening baseline), bench-specific extras, and the full metrics
//! snapshot. [`BenchArtifact::write`] also honors `PALLAS_TRACE_OUT`,
//! so a bench run can leave a Perfetto-loadable timeline next to its
//! numbers.

use crate::coordinator::protocol::Json;
use std::collections::BTreeMap;

/// Schema tag stamped into every artifact.
pub const SCHEMA: &str = "pallas.bench.v1";

/// One bench run's machine-readable summary.
#[derive(Debug, Clone)]
pub struct BenchArtifact {
    /// Bench id (`t1`, `f3`, …) — names the output file.
    pub id: String,
    /// Human config tag (dataset scale, rules swept, grid shape).
    pub config: String,
    /// Total bench wall time in seconds.
    pub wall_seconds: f64,
    /// Mean rejection ratio over the runs that screened (if meaningful).
    pub mean_rejection: Option<f64>,
    /// Speedup vs the no-screening baseline (if the bench measures one).
    pub speedup: Option<f64>,
    /// Bench-specific extras (row counts, thresholds, per-rule numbers).
    pub extra: BTreeMap<String, Json>,
}

impl BenchArtifact {
    /// Starts an artifact for bench `id` with a config tag.
    pub fn new(id: impl Into<String>, config: impl Into<String>) -> Self {
        BenchArtifact {
            id: id.into(),
            config: config.into(),
            wall_seconds: 0.0,
            mean_rejection: None,
            speedup: None,
            extra: BTreeMap::new(),
        }
    }

    /// Sets the wall time.
    pub fn wall_seconds(mut self, secs: f64) -> Self {
        self.wall_seconds = secs;
        self
    }

    /// Sets the headline mean rejection ratio.
    pub fn mean_rejection(mut self, r: f64) -> Self {
        self.mean_rejection = Some(r);
        self
    }

    /// Sets the headline speedup vs no screening.
    pub fn speedup(mut self, s: f64) -> Self {
        self.speedup = Some(s);
        self
    }

    /// Attaches a bench-specific extra field.
    pub fn extra(mut self, key: impl Into<String>, value: Json) -> Self {
        self.extra.insert(key.into(), value);
        self
    }

    /// The artifact as JSON: schema tag, headline fields, extras, and
    /// the current global metrics snapshot under `"metrics"`.
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| match v {
            Some(x) if x.is_finite() => Json::Num(x),
            _ => Json::Null,
        };
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.into())),
            ("id", Json::Str(self.id.clone())),
            ("config", Json::Str(self.config.clone())),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("mean_rejection", opt(self.mean_rejection)),
            ("speedup", opt(self.speedup)),
            ("extra", Json::Obj(self.extra.clone())),
            ("metrics", crate::telemetry::global().snapshot().to_json()),
        ])
    }

    /// Writes `BENCH_<id>.json` in the current directory, reports it on
    /// stdout, and honors `PALLAS_TRACE_OUT` (Chrome trace alongside
    /// the numbers). Returns the path written.
    pub fn write(&self) -> std::io::Result<String> {
        let path = format!("BENCH_{}.json", self.id);
        let body = self.to_json().encode();
        std::fs::write(&path, &body)?;
        println!("[bench] wrote {path} ({} bytes)", body.len());
        crate::telemetry::trace::write_from_env();
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse;

    #[test]
    fn artifact_json_has_schema_and_headline_fields() {
        crate::telemetry::global().counter("bench.test.touch").inc();
        let art = BenchArtifact::new("t9", "trio scale=1.0 rules=all")
            .wall_seconds(1.25)
            .mean_rejection(0.8)
            .speedup(2.5)
            .extra("rows", Json::Num(42.0));
        let doc = parse(&art.to_json().encode()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(doc.get("id").unwrap().as_str(), Some("t9"));
        assert_eq!(doc.get("wall_seconds").unwrap().as_f64(), Some(1.25));
        assert_eq!(doc.get("mean_rejection").unwrap().as_f64(), Some(0.8));
        assert_eq!(doc.get("speedup").unwrap().as_f64(), Some(2.5));
        assert_eq!(
            doc.get("extra").unwrap().get("rows").unwrap().as_f64(),
            Some(42.0)
        );
        // The metrics snapshot rides along.
        assert!(doc.get("metrics").unwrap().get("counters").is_some());
    }

    #[test]
    fn missing_headlines_encode_as_null() {
        let doc =
            parse(&BenchArtifact::new("x", "cfg").to_json().encode()).unwrap();
        assert_eq!(doc.get("mean_rejection"), Some(&Json::Null));
        assert_eq!(doc.get("speedup"), Some(&Json::Null));
    }
}
