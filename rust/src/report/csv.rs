//! Tiny CSV writer (no serde in the vendored crate set).
//!
//! Only the writing direction is needed: benches emit CSV series that the
//! experiment log references. Quoting follows RFC 4180.

use std::io::Write;
use std::path::Path;

/// Encodes one CSV row (quoting cells containing `, " \n`).
pub fn encode_row<S: AsRef<str>>(cells: &[S]) -> String {
    cells
        .iter()
        .map(|c| encode_cell(c.as_ref()))
        .collect::<Vec<_>>()
        .join(",")
}

fn encode_cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Streaming CSV writer.
pub struct CsvWriter<W: Write> {
    inner: W,
}

impl<W: Write> CsvWriter<W> {
    /// Wraps a writer.
    pub fn new(inner: W) -> Self {
        CsvWriter { inner }
    }

    /// Writes one row.
    pub fn write_row<S: AsRef<str>>(&mut self, cells: &[S]) -> std::io::Result<()> {
        writeln!(self.inner, "{}", encode_row(cells))
    }

    /// Writes a row of displayable values.
    pub fn write_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> std::io::Result<()> {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.write_row(&cells)
    }
}

/// Writes a whole table of rows to a file path, creating parent dirs.
pub fn write_file<P: AsRef<Path>, S: AsRef<str>>(
    path: P,
    headers: &[S],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = CsvWriter::new(std::io::BufWriter::new(std::fs::File::create(path)?));
    w.write_row(headers)?;
    for r in rows {
        w.write_row(r)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_cells() {
        assert_eq!(encode_row(&["a", "b", "1.5"]), "a,b,1.5");
    }

    #[test]
    fn quoting() {
        assert_eq!(encode_row(&["a,b", "c\"d"]), "\"a,b\",\"c\"\"d\"");
        assert_eq!(encode_row(&["x\ny"]), "\"x\ny\"");
    }

    #[test]
    fn writer_accumulates() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::new(&mut buf);
            w.write_row(&["h1", "h2"]).unwrap();
            w.write_display(&[1, 2]).unwrap();
        }
        assert_eq!(String::from_utf8(buf).unwrap(), "h1,h2\n1,2\n");
    }

    #[test]
    fn write_file_creates_dirs() {
        let dir = std::env::temp_dir().join("svmscreen_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("sub/out.csv");
        write_file(&path, &["a"], &[vec!["1".into()]]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
