//! Minimal ASCII table builder for experiment output.
//!
//! Benches print paper-shaped tables with this; examples use it for
//! human-readable summaries.

use std::fmt;

/// A simple left/right-aligned ASCII table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Creates a table with the given title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Appends a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: appends a row of displayable items.
    pub fn row_display<T: fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Renders as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&crate::report::csv::encode_row(&self.headers));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&crate::report::csv::encode_row(r));
            out.push('\n');
        }
        out
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        if !self.title.is_empty() {
            writeln!(f, "== {} ==", self.title)?;
        }
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        writeln!(f, "{}", fmt_row(&self.headers))?;
        writeln!(f, "{sep}")?;
        for row in &self.rows {
            writeln!(f, "{}", fmt_row(row))?;
        }
        Ok(())
    }
}

/// Formats a float with engineering-friendly precision.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.2}")
    } else if v.abs() >= 0.001 {
        format!("{v:.4}")
    } else {
        format!("{v:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["alpha".into(), "1".into()]);
        t.row(&["b".into(), "12345".into()]);
        let s = t.to_string();
        assert!(s.contains("== demo =="));
        assert!(s.contains("alpha"));
        // header separator present
        assert!(s.contains("-+-"));
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_export() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_display(&[1.5, 2.5]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1.5,2.5\n");
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.4), "1234");
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(0.5), "0.5000");
        assert_eq!(fnum(1e-6), "1.000e-6");
    }
}
