//! Timing and measurement substrate.
//!
//! The vendored crate set has no `criterion`, so `benches/` uses
//! [`BenchStats::measure`]: warmup runs, then N timed samples, reported as
//! median with p10/p90 spread — robust to scheduler noise in a container.

use std::time::{Duration, Instant};

/// A simple cumulative stopwatch with named restarts.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    total: Duration,
    running: bool,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Creates a running stopwatch.
    pub fn new() -> Self {
        Stopwatch { start: Instant::now(), total: Duration::ZERO, running: true }
    }

    /// Creates a paused stopwatch with zero accumulated time.
    pub fn paused() -> Self {
        Stopwatch { start: Instant::now(), total: Duration::ZERO, running: false }
    }

    /// Resumes accumulation.
    pub fn resume(&mut self) {
        if !self.running {
            self.start = Instant::now();
            self.running = true;
        }
    }

    /// Pauses accumulation.
    pub fn pause(&mut self) {
        if self.running {
            self.total += self.start.elapsed();
            self.running = false;
        }
    }

    /// Accumulated seconds (includes the live segment if running).
    pub fn seconds(&self) -> f64 {
        let mut t = self.total;
        if self.running {
            t += self.start.elapsed();
        }
        t.as_secs_f64()
    }
}

/// Robust summary of repeated timing samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Sorted per-iteration durations (seconds).
    pub samples: Vec<f64>,
}

impl BenchStats {
    /// Runs `f` `warmup` times unmeasured, then `samples` times measured.
    pub fn measure<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Self {
        for _ in 0..warmup {
            f();
        }
        let mut out = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            f();
            out.push(t0.elapsed().as_secs_f64());
        }
        out.sort_by(f64::total_cmp);
        BenchStats { samples: out }
    }

    /// Builds from raw (unsorted) samples. NaN samples are dropped —
    /// they carry no timing information and a `partial_cmp(..).unwrap()`
    /// sort would panic on them (a NaN can reach here from, e.g., a
    /// failed external measurement fed through [`BenchStats`]).
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        samples.retain(|v| !v.is_nan());
        samples.sort_by(f64::total_cmp);
        BenchStats { samples }
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let pos = q * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    /// Median sample (seconds).
    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// 10th percentile (seconds).
    pub fn p10(&self) -> f64 {
        self.quantile(0.1)
    }

    /// 90th percentile (seconds).
    pub fn p90(&self) -> f64 {
        self.quantile(0.9)
    }

    /// Mean (seconds).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Human-readable one-liner: `median [p10 .. p90]`.
    pub fn display(&self) -> String {
        format!(
            "{} [{} .. {}]",
            fmt_duration(self.median()),
            fmt_duration(self.p10()),
            fmt_duration(self.p90())
        )
    }
}

/// Pretty-prints a duration in adaptive units. Negative values (clock
/// skew, subtracted timestamps) keep their sign instead of falling
/// into the nanosecond branch.
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        "n/a".into()
    } else if secs < 0.0 {
        format!("-{}", fmt_duration(-secs))
    } else if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::paused();
        assert_eq!(sw.seconds(), 0.0);
        sw.resume();
        std::thread::sleep(Duration::from_millis(5));
        sw.pause();
        let t1 = sw.seconds();
        assert!(t1 >= 0.004, "{t1}");
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(sw.seconds(), t1); // paused: unchanged
    }

    #[test]
    fn stats_quantiles() {
        let s = BenchStats::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.mean(), 3.0);
        assert!(s.p10() >= 1.0 && s.p10() <= 2.0);
        assert!(s.p90() >= 4.0 && s.p90() <= 5.0);
    }

    #[test]
    fn measure_counts_iterations() {
        let mut count = 0;
        let s = BenchStats::measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.samples.len(), 5);
        assert!(s.median() >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500us");
        assert_eq!(fmt_duration(2.5e-8), "25ns");
        assert_eq!(fmt_duration(f64::NAN), "n/a");
        assert_eq!(fmt_duration(-0.0025), "-2.500ms");
        assert_eq!(fmt_duration(-2.5), "-2.500s");
    }

    #[test]
    fn nan_samples_do_not_panic_and_are_dropped() {
        let s = BenchStats::from_samples(vec![2.0, f64::NAN, 1.0, f64::NAN, 3.0]);
        assert_eq!(s.samples, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.median(), 2.0);
        // all-NaN input degrades to the empty-stats path
        let empty = BenchStats::from_samples(vec![f64::NAN, f64::NAN]);
        assert!(empty.samples.is_empty());
        assert!(empty.median().is_nan());
        assert!(empty.mean().is_nan());
        assert_eq!(empty.display(), "n/a [n/a .. n/a]");
    }
}
