//! Reporting substrate: ASCII tables, CSV emission, timers and bench
//! statistics. The vendored crate set has no `criterion`, so the bench
//! harness in `benches/` builds on [`timer::BenchStats`].

pub mod csv;
pub mod table;
pub mod timer;

pub use table::Table;
pub use timer::{BenchStats, Stopwatch};
