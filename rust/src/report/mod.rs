//! Reporting substrate: ASCII tables, CSV emission, timers, bench
//! statistics and Prometheus text rendering. The vendored crate set has
//! no `criterion`, so the bench harness in `benches/` builds on
//! [`timer::BenchStats`]; [`prometheus`] renders live telemetry
//! snapshots for scrapers, and [`bench`] standardizes the
//! `BENCH_<id>.json` artifacts every experiment emits for CI.

pub mod bench;
pub mod csv;
pub mod diag;
pub mod prometheus;
pub mod table;
pub mod timer;

pub use bench::BenchArtifact;
pub use table::Table;
pub use timer::{BenchStats, Stopwatch};
