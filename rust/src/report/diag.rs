//! Exporters for diag-ledger verdicts: JSONL (one verdict per line)
//! and RFC-4180 CSV. Used by `pallas explain --export FILE` and the
//! `f1_rejection` bench's CI artifact.

use crate::diag::ledger::Verdict;
use std::path::Path;

/// Renders verdicts as JSONL — one flat JSON object per line.
pub fn to_jsonl(records: &[Verdict]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json().encode());
        out.push('\n');
    }
    out
}

/// Writes verdicts as a JSONL file (parent directories created).
pub fn write_jsonl<P: AsRef<Path>>(path: P, records: &[Verdict]) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, to_jsonl(records))
}

/// Writes verdicts as a CSV file with the [`Verdict::CSV_HEADER`]
/// columns.
pub fn write_csv<P: AsRef<Path>>(path: P, records: &[Verdict]) -> std::io::Result<()> {
    let rows: Vec<Vec<String>> = records.iter().map(Verdict::csv_row).collect();
    super::csv::write_file(path, &Verdict::CSV_HEADER, &rows)
}

/// Writes verdicts choosing the format by extension: `.csv` → CSV,
/// anything else → JSONL.
pub fn write_auto<P: AsRef<Path>>(path: P, records: &[Verdict]) -> std::io::Result<()> {
    let is_csv = path
        .as_ref()
        .extension()
        .map(|e| e.eq_ignore_ascii_case("csv"))
        .unwrap_or(false);
    if is_csv {
        write_csv(path, records)
    } else {
        write_jsonl(path, records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::parse;

    fn verdict(feature: usize, margin: f64) -> Verdict {
        Verdict {
            feature,
            rule: "paper",
            lambda1: 1.0,
            lambda2: 0.5,
            bound: 1.0 + margin,
            threshold: 1.0,
            margin,
            kept: margin >= 0.0,
            near_miss: margin.abs() < 1e-2,
            source: "seq",
            sweep: 0,
        }
    }

    #[test]
    fn jsonl_round_trips_per_line() {
        let text = to_jsonl(&[verdict(0, 0.5), verdict(1, -1e-3)]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = parse(lines[1]).unwrap();
        assert_eq!(v.get("feature").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("near_miss"), Some(&crate::coordinator::protocol::Json::Bool(true)));
    }

    #[test]
    fn auto_picks_format_by_extension() {
        let dir = std::env::temp_dir().join("svmscreen_diag_export_test");
        let _ = std::fs::remove_dir_all(&dir);
        let records = [verdict(3, 2e-3)];
        let csv_path = dir.join("out.csv");
        let jsonl_path = dir.join("out.jsonl");
        write_auto(&csv_path, &records).unwrap();
        write_auto(&jsonl_path, &records).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert!(csv.starts_with("sweep,feature,rule"), "{csv}");
        assert_eq!(csv.lines().count(), 2);
        let jsonl = std::fs::read_to_string(&jsonl_path).unwrap();
        assert!(jsonl.starts_with('{'), "{jsonl}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
