//! In-house property-testing harness (the vendored crate set has no
//! `proptest`).
//!
//! [`property`] runs a closure over `cases` seeded inputs; on failure it
//! reports the failing seed so the case reproduces exactly (every
//! generator in this crate is a pure function of its seed). This covers
//! the coordinator/screening invariants DESIGN.md §5 lists.

use crate::data::synth::Pcg32;

/// Runs `body(case_rng)` for `cases` deterministic cases derived from
/// `seed`. Panics with the failing case seed embedded in the message.
pub fn property<F: FnMut(&mut Pcg32)>(name: &str, seed: u64, cases: usize, mut body: F) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Pcg32::seeded(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Asserts two floats agree to a relative-or-absolute tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "{what}: {a} vs {b} (diff {:.3e}, tol {tol:.1e})",
        (a - b).abs()
    );
}

/// Asserts `lo <= x` with tolerance — used for "bound must dominate"
/// safety properties.
#[track_caller]
pub fn assert_dominates(bound: f64, value: f64, tol: f64, what: &str) {
    assert!(
        bound >= value - tol * (1.0 + value.abs()),
        "{what}: bound {bound} < value {value} (violation {:.3e})",
        value - bound
    );
}

/// Uniform f64 in `[lo, hi)`.
pub fn uniform(rng: &mut Pcg32, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property("count", 1, 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn property_reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            property("boom", 2, 10, |rng| {
                // fail deterministically on some case
                assert!(rng.f64() < 0.95, "drew a large value");
            })
        });
        let err = r.expect_err("should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("case_seed="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn close_and_dominates() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, "eq");
        assert_dominates(2.0, 1.5, 1e-9, "dom");
        assert_dominates(1.5, 1.5 + 1e-12, 1e-9, "edge");
    }

    #[test]
    #[should_panic]
    fn dominates_detects_violation() {
        assert_dominates(1.0, 2.0, 1e-9, "viol");
    }

    #[test]
    fn uniform_range() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..100 {
            let v = uniform(&mut rng, -2.0, 5.0);
            assert!((-2.0..5.0).contains(&v));
        }
    }
}
