//! Solver-facing API: options, reports and the [`Solver`] trait.

use crate::data::FeatureMatrix;
use crate::error::Result;
use crate::svm::dual::GapReport;

/// Convergence and iteration controls shared by all solvers.
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// Maximum outer iterations (CD epochs / FISTA steps).
    pub max_iter: usize,
    /// Target *relative* duality gap (`gap / max(1,|P|)`).
    pub tol: f64,
    /// Check the duality gap every this many outer iterations.
    /// The check is O(nnz), so it is amortized.
    pub gap_check_every: usize,
    /// CD only: run this many consecutive active-set-only passes between
    /// full passes (0 disables the active-set heuristic).
    pub active_set_passes: usize,
    /// Record `(iteration, rel_gap)` at every gap check (F4 experiment).
    pub record_gap_trace: bool,
    /// CD only: dynamic (gap-ball) screening — at every gap check,
    /// freeze coordinates the current certificate proves inactive
    /// ([`crate::screening::gapball`]). Safe; orthogonal to the
    /// sequential rule (which shrinks the problem *before* the solve).
    pub dynamic_screen: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iter: 2000,
            tol: 1e-6,
            gap_check_every: 10,
            active_set_passes: 5,
            record_gap_trace: false,
            dynamic_screen: false,
        }
    }
}

impl SolveOptions {
    /// High-precision preset used by safety audits (gap ≤ 1e−9).
    pub fn precise() -> Self {
        SolveOptions { max_iter: 20_000, tol: 1e-9, ..Default::default() }
    }
}

/// The outcome of one solve.
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// Optimal weights (length m).
    pub w: Vec<f64>,
    /// Optimal bias.
    pub b: f64,
    /// λ that was solved.
    pub lambda: f64,
    /// Outer iterations consumed.
    pub iterations: usize,
    /// Final duality-gap certificate.
    pub gap: GapReport,
    /// Whether `gap.rel_gap <= tol` was reached within `max_iter`.
    pub converged: bool,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// `(iteration, rel_gap)` samples (when `record_gap_trace`).
    pub gap_trace: Vec<(usize, f64)>,
    /// Convergence anomalies (stalls / divergence / non-finite gaps)
    /// flagged by the diag monitor ([`crate::diag::convergence`]).
    pub anomalies: usize,
}

impl SolveReport {
    /// Indices of active (non-zero) features.
    pub fn active_set(&self) -> Vec<usize> {
        self.w
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(j, _)| j)
            .collect()
    }

    /// Number of non-zero weights.
    pub fn nnz(&self) -> usize {
        self.w.iter().filter(|v| **v != 0.0).count()
    }
}

/// A solver for the L1-regularized L2-loss SVM primal.
pub trait Solver {
    /// Solves at `lambda`, optionally warm-starting from `w0`.
    fn solve<X: FeatureMatrix>(
        &self,
        x: &X,
        y: &[f64],
        lambda: f64,
        w0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> Result<SolveReport>;
}

/// Which solver implementation to use (enum dispatch — the trait has a
/// generic method, so it is not object-safe).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// Cyclic coordinate descent (default).
    Cd,
    /// Accelerated proximal gradient.
    Fista,
}

impl SolverKind {
    /// Parses `"cd" | "fista"`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cd" => Some(SolverKind::Cd),
            "fista" => Some(SolverKind::Fista),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Cd => "cd",
            SolverKind::Fista => "fista",
        }
    }
}

/// Dispatches to the chosen solver.
pub fn solve<X: FeatureMatrix>(
    kind: SolverKind,
    x: &X,
    y: &[f64],
    lambda: f64,
    w0: Option<&[f64]>,
    opts: &SolveOptions,
) -> Result<SolveReport> {
    solve_with_curvature(kind, x, y, lambda, w0, opts, None)
}

/// [`solve`] with an optional precomputed per-column curvature vector
/// `H_j = ‖f_j‖²` (length m), e.g. from a path-wide
/// [`crate::data::cache::FeatureCache`]. CD skips its O(nnz) per-solve
/// column-norm pass and uses the slice; FISTA ignores it (its Lipschitz
/// estimate is a power iteration over the whole matrix).
pub fn solve_with_curvature<X: FeatureMatrix>(
    kind: SolverKind,
    x: &X,
    y: &[f64],
    lambda: f64,
    w0: Option<&[f64]>,
    opts: &SolveOptions,
    curvature: Option<&[f64]>,
) -> Result<SolveReport> {
    let _span = crate::telemetry::Span::enter_labeled(
        format!("solver.{}", kind.name()),
        Some(format!("lambda={lambda:.4e}")),
    );
    match kind {
        SolverKind::Cd => crate::solver::cd::CdSolver::default()
            .solve_with_curvature(x, y, lambda, w0, opts, curvature),
        SolverKind::Fista => {
            crate::solver::fista::FistaSolver::default().solve(x, y, lambda, w0, opts)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        assert_eq!(SolverKind::parse("cd"), Some(SolverKind::Cd));
        assert_eq!(SolverKind::parse("fista"), Some(SolverKind::Fista));
        assert_eq!(SolverKind::parse("sgd"), None);
        assert_eq!(SolverKind::Cd.name(), "cd");
    }

    #[test]
    fn defaults_are_sane() {
        let o = SolveOptions::default();
        assert!(o.tol > 0.0 && o.max_iter > 0 && o.gap_check_every > 0);
        let p = SolveOptions::precise();
        assert!(p.tol < o.tol);
    }
}
