//! Solvers for the L1-regularized L2-loss SVM primal (Eq. 1 / Eq. 23).
//!
//! The screening rule is solver-agnostic; we ship two independent
//! solvers so the experiments can demonstrate that:
//!
//! * [`cd`] — cyclic coordinate descent with majorize-minimize proximal
//!   Newton steps (LIBLINEAR-family), the fast default for sparse data.
//! * [`fista`] — accelerated proximal gradient with adaptive restart,
//!   matching the structure of the AOT/PJRT execution path (the gradient
//!   is one dense panel op, which the L2 JAX graph also implements).
//!
//! Both terminate on a *certified* relative duality gap
//! ([`crate::svm::dual::duality_gap`]), so "solved" always means "provably
//! within tol of the optimum" — the precision the safety experiments
//! rely on.

pub mod api;
pub mod cd;
pub mod fista;
pub mod reduced;

pub use api::{solve, SolveOptions, SolveReport, Solver, SolverKind};
pub use reduced::{scatter_solution, ReducedProblem};
