//! Cyclic coordinate descent for the L1-regularized L2-loss SVM.
//!
//! Per coordinate `j`, the squared-hinge loss restricted to `w_j` has
//! curvature at most `H_j = ‖f_j‖²` (each sample's loss'' w.r.t. the
//! margin is 1 on its active set, 0 elsewhere). The update minimizes the
//! majorizing model
//!
//! ```text
//! q(d) = g_j d + ½ H_j d² + λ|w_j + d|,   g_j = −f_jᵀ(ξ∘y),
//! ```
//!
//! whose closed form is a soft-threshold step
//! `w_j ← S(w_j − g_j/H_j, λ/H_j)`. Because `q` majorizes the true
//! objective difference, every step is guaranteed descent — no line
//! search needed (LIBLINEAR-family, MM variant).
//!
//! After each sweep the bias is re-optimized *exactly*
//! ([`crate::svm::objective::optimal_bias`]) — which both accelerates
//! convergence and makes the duality-gap certificate valid.
//!
//! The active-set heuristic alternates one full sweep with
//! `opts.active_set_passes` sweeps over the currently-nonzero features —
//! the standard trick that makes path solving with warm starts fast, and
//! exactly the structure screening accelerates further (fewer features in
//! the full sweeps).

use crate::data::synth::Pcg32;
use crate::data::FeatureMatrix;
use crate::error::{Error, Result};
use crate::solver::api::{SolveOptions, SolveReport, Solver};
use crate::svm::dual::duality_gap;
use crate::svm::objective::optimal_bias;

/// Coordinate-descent solver configuration.
#[derive(Debug, Clone)]
pub struct CdSolver {
    /// Shuffle coordinate order each epoch (deterministic PCG stream).
    pub shuffle: bool,
    /// Seed for the shuffle stream.
    pub seed: u64,
}

impl Default for CdSolver {
    fn default() -> Self {
        CdSolver { shuffle: true, seed: 0xC0FFEE }
    }
}

/// Scalar soft-threshold `S(u, t) = sign(u)·max(|u|−t, 0)`.
#[inline]
pub fn soft_threshold(u: f64, t: f64) -> f64 {
    if u > t {
        u - t
    } else if u < -t {
        u + t
    } else {
        0.0
    }
}

impl Solver for CdSolver {
    fn solve<X: FeatureMatrix>(
        &self,
        x: &X,
        y: &[f64],
        lambda: f64,
        w0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        self.solve_with_curvature(x, y, lambda, w0, opts, None)
    }
}

impl CdSolver {
    /// [`Solver::solve`] with the curvature vector `H_j = ‖f_j‖²`
    /// optionally supplied by the caller (e.g. the path-wide
    /// [`crate::data::cache::FeatureCache`]), skipping the per-solve
    /// O(nnz) column-norm pass.
    pub fn solve_with_curvature<X: FeatureMatrix>(
        &self,
        x: &X,
        y: &[f64],
        lambda: f64,
        w0: Option<&[f64]>,
        opts: &SolveOptions,
        curvature: Option<&[f64]>,
    ) -> Result<SolveReport> {
        let t0 = std::time::Instant::now();
        let n = x.n_samples();
        let m = x.n_features();
        if lambda <= 0.0 {
            return Err(Error::solver("lambda must be positive"));
        }
        if y.len() != n {
            return Err(Error::solver("label length mismatch"));
        }

        let mut w = match w0 {
            Some(w0) => {
                if w0.len() != m {
                    return Err(Error::solver("warm-start length mismatch"));
                }
                w0.to_vec()
            }
            None => vec![0.0; m],
        };

        // Column curvature bounds: caller-provided or a per-solve pass.
        let h_storage;
        let h: &[f64] = match curvature {
            Some(h) => {
                if h.len() != m {
                    return Err(Error::solver("curvature length mismatch"));
                }
                h
            }
            None => {
                h_storage = (0..m).map(|j| x.col_norm_sq(j)).collect::<Vec<f64>>();
                &h_storage
            }
        };

        // Scores z = Xw and exact bias.
        let mut z = vec![0.0; n];
        x.matvec(&w, &mut z);
        let mut b = optimal_bias(y, &z);

        let mut order: Vec<usize> = (0..m).collect();
        let mut sweep_buf: Vec<usize> = Vec::with_capacity(m);
        // Dynamic screening state: frozen coordinates are provably zero
        // at the optimum (gap-ball certificate) and leave every sweep.
        let mut frozen = vec![false; m];
        let mut n_frozen = 0usize;
        let mut rng = Pcg32::new(self.seed, 0x5eed);

        let mut last_gap = None;
        let mut converged = false;
        let mut iterations = 0;
        let mut gap_trace = Vec::new();
        let mut monitor = crate::diag::convergence::Monitor::new("cd", lambda);

        'outer: for epoch in 0..opts.max_iter {
            iterations = epoch + 1;
            let full_pass = opts.active_set_passes == 0
                || epoch % (opts.active_set_passes + 1) == 0;

            // Coordinate set for this sweep (no per-epoch allocation:
            // full passes iterate `order` in place, active passes reuse
            // a persistent buffer — Perf §P3).
            let sweep: &[usize] = if full_pass {
                if self.shuffle {
                    rng.shuffle(&mut order);
                }
                &order
            } else {
                sweep_buf.clear();
                sweep_buf.extend((0..m).filter(|&j| w[j] != 0.0 && !frozen[j]));
                &sweep_buf
            };

            let mut max_delta = 0.0f64;
            for &j in sweep {
                if frozen[j] {
                    continue;
                }
                let hj = h[j];
                if hj <= 0.0 {
                    // Zero column: with λ>0 its optimal weight is 0.
                    if w[j] != 0.0 {
                        w[j] = 0.0;
                    }
                    continue;
                }
                // g_j = -Σ_{i ∈ supp(f_j)} x_ij y_i ξ_i, fused in one pass
                // through the backend-specialized method (Perf §P1).
                let g = x.col_sqhinge_grad(j, y, &z, b);
                let u = w[j] - g / hj;
                let w_new = soft_threshold(u, lambda / hj);
                let d = w_new - w[j];
                if d != 0.0 {
                    x.col_axpy(j, d, &mut z);
                    w[j] = w_new;
                    max_delta = max_delta.max(d.abs());
                }
            }
            // Exact bias step, warm-started at the previous bias (P3).
            b = crate::svm::objective::optimal_bias_from(y, &z, b);

            // Cheap inner stall check on full passes: if nothing moved and
            // we just did a full sweep, we are at a (coordinate-wise)
            // stationary point — verify with the gap immediately.
            let force_check = full_pass && max_delta < 1e-14;
            if force_check || (epoch + 1) % opts.gap_check_every == 0 {
                let (rep, dual, _) = duality_gap(x, y, &w, lambda);
                b = rep_bias_consistency(&rep, b);
                last_gap = Some(rep);
                if opts.record_gap_trace {
                    gap_trace.push((epoch + 1, rep.rel_gap));
                }
                monitor.observe(epoch + 1, rep.rel_gap);
                crate::tele_trace!(
                    "solver.cd",
                    "epoch {} rel_gap {:.3e} frozen {}",
                    epoch + 1,
                    rep.rel_gap,
                    n_frozen
                );
                if rep.rel_gap <= opts.tol {
                    converged = true;
                    break 'outer;
                }
                if opts.dynamic_screen {
                    // Gap-ball dynamic screening: freeze coordinates the
                    // current certificate proves inactive. Any frozen
                    // coordinate with a nonzero iterate is snapped to 0
                    // (its optimal value) with the scores updated.
                    let bounds =
                        crate::screening::gapball::gap_ball_bounds(x, y, &dual, rep.gap);
                    for j in 0..m {
                        if !frozen[j]
                            && bounds[j] < crate::screening::rule::KEEP_THRESHOLD
                        {
                            frozen[j] = true;
                            n_frozen += 1;
                            if w[j] != 0.0 {
                                x.col_axpy(j, -w[j], &mut z);
                                w[j] = 0.0;
                            }
                        }
                    }
                    let _ = n_frozen;
                }
                if force_check {
                    // Coordinate-stationary but gap not met: with an exact
                    // MM model this should not happen except at numerical
                    // precision limits; stop rather than spin.
                    break 'outer;
                }
            }
        }

        let gap = match last_gap {
            Some(g) => g,
            None => duality_gap(x, y, &w, lambda).0,
        };
        let seconds = t0.elapsed().as_secs_f64();
        let tele = crate::telemetry::global();
        tele.counter("solver.cd.solves").inc();
        tele.counter("solver.cd.epochs").add(iterations as u64);
        tele.counter("solver.cd.frozen_coords").add(n_frozen as u64);
        tele.histogram("solver.cd.seconds").record(seconds);
        crate::tele_debug!(
            "solver.cd",
            "lambda {lambda:.4e}: {} epochs, rel_gap {:.3e}, converged {} in {}",
            iterations,
            gap.rel_gap,
            converged,
            crate::report::timer::fmt_duration(seconds)
        );
        let anomalies = monitor.finish(iterations, converged, gap.rel_gap);
        Ok(SolveReport {
            w,
            b,
            lambda,
            iterations,
            gap,
            converged,
            seconds,
            gap_trace,
            anomalies,
        })
    }
}

// The gap report recomputed the optimal bias internally; keep the
// solver's bias consistent with the certificate it returns.
fn rep_bias_consistency(_rep: &crate::svm::dual::GapReport, b: f64) -> f64 {
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::svm::kkt::kkt_audit;
    use crate::svm::problem::Problem;
    use crate::testkit::assert_close;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let ds = SynthSpec::dense(50, 15, 31).generate();
        let p = Problem::from_dataset(&ds);
        let rep = CdSolver::default()
            .solve(&p.x, &p.y, p.lambda_max() * 1.0001, None, &SolveOptions::default())
            .unwrap();
        assert!(rep.converged, "gap {:?}", rep.gap);
        assert_eq!(rep.nnz(), 0, "w must be 0 at lambda >= lambda_max");
        assert_close(rep.b, p.b_star(), 1e-6, "bias at w=0");
    }

    #[test]
    fn nonzero_solution_below_lambda_max() {
        let ds = SynthSpec::dense(50, 15, 31).generate();
        let p = Problem::from_dataset(&ds);
        let rep = CdSolver::default()
            .solve(&p.x, &p.y, 0.9 * p.lambda_max(), None, &SolveOptions::default())
            .unwrap();
        assert!(rep.converged);
        assert!(rep.nnz() > 0, "expected active features just below lambda_max");
        // First active features should include the §5 first-feature.
        let first = &p.lambda_max_stats().first_features;
        assert!(
            first.iter().any(|j| rep.w[*j] != 0.0),
            "first feature {first:?} not active; active = {:?}",
            rep.active_set()
        );
    }

    #[test]
    fn kkt_satisfied_at_solution() {
        let ds = SynthSpec::text(60, 200, 33).generate();
        let p = Problem::from_dataset(&ds);
        let lambda = 0.3 * p.lambda_max();
        let rep = CdSolver::default()
            .solve(&p.x, &p.y, lambda, None, &SolveOptions::precise())
            .unwrap();
        assert!(rep.converged, "gap {:?}", rep.gap);
        let theta =
            crate::svm::dual::theta_from_primal(&p.x, &p.y, &rep.w, rep.b, lambda);
        let audit = kkt_audit(&p.x, &p.y, &rep.w, &theta, 1e-3);
        assert_eq!(audit.sign_violations, 0, "{audit:?}");
        assert!(audit.max_active_dev < 1e-2, "{audit:?}");
        assert!(audit.max_inactive <= 1.0 + 1e-3, "{audit:?}");
    }

    #[test]
    fn warm_start_converges_faster() {
        let ds = SynthSpec::dense(80, 40, 35).generate();
        let p = Problem::from_dataset(&ds);
        let opts = SolveOptions { tol: 1e-8, gap_check_every: 1, ..Default::default() };
        let lam1 = 0.5 * p.lambda_max();
        let lam2 = 0.45 * p.lambda_max();
        let rep1 = CdSolver::default().solve(&p.x, &p.y, lam1, None, &opts).unwrap();
        let cold = CdSolver::default().solve(&p.x, &p.y, lam2, None, &opts).unwrap();
        let warm =
            CdSolver::default().solve(&p.x, &p.y, lam2, Some(&rep1.w), &opts).unwrap();
        assert!(warm.converged && cold.converged);
        assert!(
            warm.iterations <= cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
    }

    #[test]
    fn rejects_bad_inputs() {
        let ds = SynthSpec::dense(10, 5, 1).generate();
        let s = CdSolver::default();
        assert!(s.solve(&ds.x, &ds.y, -1.0, None, &SolveOptions::default()).is_err());
        assert!(s
            .solve(&ds.x, &ds.y, 1.0, Some(&[0.0; 3]), &SolveOptions::default())
            .is_err());
        assert!(s.solve(&ds.x, &ds.y[..5], 1.0, None, &SolveOptions::default()).is_err());
    }

    #[test]
    fn objective_monotone_under_mm_steps() {
        // The MM guarantee: objective after solve <= objective at start.
        let ds = SynthSpec::corr(40, 20, 37).generate();
        let p = Problem::from_dataset(&ds);
        let lambda = 0.4 * p.lambda_max();
        let p0 = crate::svm::objective::primal_objective(
            &p.x, &p.y, &vec![0.0; 20], p.b_star(), lambda,
        );
        let rep = CdSolver::default()
            .solve(&p.x, &p.y, lambda, None, &SolveOptions::default())
            .unwrap();
        assert!(rep.gap.primal <= p0 + 1e-12);
    }
}

#[cfg(test)]
mod dynamic_tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::svm::problem::Problem;
    use crate::testkit::assert_close;

    /// Dynamic screening must not change the solution — same certified
    /// objective as the plain solve, on all dataset regimes.
    #[test]
    fn dynamic_screening_preserves_solution() {
        for spec in [
            SynthSpec::dense(60, 50, 701),
            SynthSpec::text(80, 200, 702),
            SynthSpec::corr(50, 40, 703),
        ] {
            let p = Problem::from_dataset(&spec.generate());
            for frac in [0.6, 0.3, 0.1] {
                let lambda = frac * p.lambda_max();
                let opts = SolveOptions { tol: 1e-8, ..Default::default() };
                let plain =
                    CdSolver::default().solve(&p.x, &p.y, lambda, None, &opts).unwrap();
                let dynamic = CdSolver::default()
                    .solve(
                        &p.x,
                        &p.y,
                        lambda,
                        None,
                        &SolveOptions { dynamic_screen: true, ..opts },
                    )
                    .unwrap();
                assert!(plain.converged && dynamic.converged);
                assert_close(
                    dynamic.gap.primal,
                    plain.gap.primal,
                    1e-6,
                    &format!("{} frac={frac}", p.name),
                );
            }
        }
    }

    /// Dynamic screening never uses more epochs than the plain solve
    /// (frozen coordinates leave the full sweeps).
    #[test]
    fn dynamic_screening_does_not_slow_convergence() {
        let p = Problem::from_dataset(&SynthSpec::text(100, 500, 705).generate());
        let lambda = 0.3 * p.lambda_max();
        let opts = SolveOptions { tol: 1e-8, gap_check_every: 5, ..Default::default() };
        let plain = CdSolver::default().solve(&p.x, &p.y, lambda, None, &opts).unwrap();
        let dynamic = CdSolver::default()
            .solve(&p.x, &p.y, lambda, None,
                   &SolveOptions { dynamic_screen: true, ..opts })
            .unwrap();
        assert!(dynamic.iterations <= plain.iterations + opts.gap_check_every,
            "dynamic {} vs plain {}", dynamic.iterations, plain.iterations);
    }
}
