//! FISTA (accelerated proximal gradient) for the L1-regularized
//! L2-loss SVM, with adaptive restart.
//!
//! The smooth part `h(w, b)` has a gradient that is Lipschitz with
//! constant `L = σ_max([X 1])²` (the squared hinge's per-sample curvature
//! is at most 1), estimated here by power iteration on the augmented
//! matrix `[X 1]` (the bias behaves as an extra unpenalized feature with
//! a constant-one column).
//!
//! The iteration is the standard Beck–Teboulle scheme with the
//! O'Donoghue–Candès function-value restart. The gradient is one dense
//! panel op per step — the same computation the L2 JAX graph
//! (`python/compile/model.py:svm_grad`) implements, which is why this
//! solver is the one that can run its hot op through the PJRT runtime.

use crate::data::FeatureMatrix;
use crate::error::{Error, Result};
use crate::solver::api::{SolveOptions, SolveReport, Solver};
use crate::solver::cd::soft_threshold;
use crate::svm::dual::duality_gap;
use crate::svm::objective::{margins, primal_gradient};

/// FISTA solver configuration.
#[derive(Debug, Clone)]
pub struct FistaSolver {
    /// Power-iteration steps for the Lipschitz estimate.
    pub power_iters: usize,
    /// Safety factor multiplied onto the Lipschitz estimate.
    pub l_safety: f64,
}

impl Default for FistaSolver {
    fn default() -> Self {
        FistaSolver { power_iters: 40, l_safety: 1.02 }
    }
}

impl FistaSolver {
    /// Estimates `σ_max([X 1])²` by power iteration.
    pub fn estimate_lipschitz<X: FeatureMatrix>(&self, x: &X) -> f64 {
        let n = x.n_samples();
        let m = x.n_features();
        // v in R^{m+1} (last entry = bias column), u in R^n.
        let mut v = vec![1.0 / ((m + 1) as f64).sqrt(); m + 1];
        let mut u = vec![0.0; n];
        let mut sigma_sq = 1.0;
        for _ in 0..self.power_iters {
            // u = X v[..m] + v[m] * 1
            x.matvec(&v[..m], &mut u);
            for ui in u.iter_mut() {
                *ui += v[m];
            }
            // v = [Xᵀu ; 1ᵀu]
            x.matvec_t(&u, &mut v[..m]);
            v[m] = u.iter().sum();
            let nrm = crate::linalg::nrm2(&v);
            if nrm == 0.0 {
                return 1.0;
            }
            sigma_sq = nrm; // ‖Aᵀ A v‖ → σ_max² as v converges
            crate::linalg::scale(1.0 / nrm, &mut v);
        }
        sigma_sq * self.l_safety
    }
}

impl Solver for FistaSolver {
    fn solve<X: FeatureMatrix>(
        &self,
        x: &X,
        y: &[f64],
        lambda: f64,
        w0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        let t0 = std::time::Instant::now();
        let n = x.n_samples();
        let m = x.n_features();
        if lambda <= 0.0 {
            return Err(Error::solver("lambda must be positive"));
        }
        if y.len() != n {
            return Err(Error::solver("label length mismatch"));
        }
        let mut w = match w0 {
            Some(w0) => {
                if w0.len() != m {
                    return Err(Error::solver("warm-start length mismatch"));
                }
                w0.to_vec()
            }
            None => vec![0.0; m],
        };

        let l = self.estimate_lipschitz(x).max(1e-12);
        let step = 1.0 / l;

        let obj = |w: &[f64], b: f64| -> f64 {
            margins(x, y, w, b).loss() + lambda * w.iter().map(|v| v.abs()).sum::<f64>()
        };

        let mut b = crate::svm::objective::optimal_bias(y, &{
            let mut z = vec![0.0; n];
            x.matvec(&w, &mut z);
            z
        });
        // Momentum state.
        let mut v_w = w.clone();
        let mut v_b = b;
        let mut t_mom = 1.0f64;
        let mut f_prev = obj(&w, b);

        let mut last_gap = None;
        let mut converged = false;
        let mut iterations = 0;
        let mut gap_trace = Vec::new();
        let mut monitor = crate::diag::convergence::Monitor::new("fista", lambda);

        for it in 0..opts.max_iter {
            iterations = it + 1;
            // Gradient at the extrapolated point (v_w, v_b).
            let mar = margins(x, y, &v_w, v_b);
            let (gw, gb) = primal_gradient(x, y, &mar);

            // Prox-gradient step.
            let mut w_new = vec![0.0; m];
            for j in 0..m {
                w_new[j] = soft_threshold(v_w[j] - step * gw[j], step * lambda);
            }
            let b_new = v_b - step * gb;

            let f_new = obj(&w_new, b_new);
            if f_new > f_prev {
                // Adaptive restart: drop momentum, retry from (w, b).
                v_w.copy_from_slice(&w);
                v_b = b;
                t_mom = 1.0;
                f_prev = f_prev.min(f_new);
            } else {
                let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_mom * t_mom).sqrt());
                let beta = (t_mom - 1.0) / t_next;
                for j in 0..m {
                    v_w[j] = w_new[j] + beta * (w_new[j] - w[j]);
                }
                v_b = b_new + beta * (b_new - b);
                t_mom = t_next;
                w.copy_from_slice(&w_new);
                b = b_new;
                f_prev = f_new;
            }

            if (it + 1) % opts.gap_check_every == 0 {
                let (rep, _, _) = duality_gap(x, y, &w, lambda);
                last_gap = Some(rep);
                if opts.record_gap_trace {
                    gap_trace.push((it + 1, rep.rel_gap));
                }
                monitor.observe(it + 1, rep.rel_gap);
                crate::tele_trace!(
                    "solver.fista",
                    "step {} rel_gap {:.3e}",
                    it + 1,
                    rep.rel_gap
                );
                if rep.rel_gap <= opts.tol {
                    converged = true;
                    break;
                }
            }
        }

        // Final exact-bias polish (free, improves the certificate).
        let (gap, dp, _) = duality_gap(x, y, &w, lambda);
        let gap = if let Some(g) = last_gap.filter(|_| converged) { g } else { gap };
        let seconds = t0.elapsed().as_secs_f64();
        let tele = crate::telemetry::global();
        tele.counter("solver.fista.solves").inc();
        tele.counter("solver.fista.steps").add(iterations as u64);
        tele.histogram("solver.fista.seconds").record(seconds);
        crate::tele_debug!(
            "solver.fista",
            "lambda {lambda:.4e}: {} steps, rel_gap {:.3e}, converged {} in {}",
            iterations,
            gap.rel_gap,
            converged,
            crate::report::timer::fmt_duration(seconds)
        );
        let anomalies = monitor.finish(iterations, converged, gap.rel_gap);
        Ok(SolveReport {
            w,
            b: dp.b,
            lambda,
            iterations,
            gap,
            converged,
            seconds,
            gap_trace,
            anomalies,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::solver::api::{solve, SolverKind};
    use crate::svm::problem::Problem;
    use crate::testkit::assert_close;

    #[test]
    fn lipschitz_dominates_column_norms() {
        // σ_max² >= max_j ‖f_j‖² for the augmented matrix.
        let ds = SynthSpec::dense(30, 10, 41).generate();
        let l = FistaSolver::default().estimate_lipschitz(&ds.x);
        for j in 0..10 {
            assert!(l >= ds.x.col_norm_sq(j) * 0.99, "L={l} too small");
        }
        // and >= n (the bias column's norm²)
        assert!(l >= 30.0 * 0.99);
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let ds = SynthSpec::dense(40, 12, 43).generate();
        let p = Problem::from_dataset(&ds);
        let rep = FistaSolver::default()
            .solve(&p.x, &p.y, 1.001 * p.lambda_max(), None, &SolveOptions::default())
            .unwrap();
        assert!(rep.converged, "{:?}", rep.gap);
        // FISTA iterates may carry tiny weights; they must be ~0.
        assert!(rep.w.iter().all(|v| v.abs() < 1e-6), "max |w| = {:?}",
            rep.w.iter().fold(0.0f64, |a, v| a.max(v.abs())));
    }

    #[test]
    fn agrees_with_cd() {
        let ds = SynthSpec::dense(60, 25, 47).generate();
        let p = Problem::from_dataset(&ds);
        let lambda = 0.4 * p.lambda_max();
        let opts = SolveOptions { tol: 1e-7, max_iter: 30000, ..Default::default() };
        let cd = solve(SolverKind::Cd, &p.x, &p.y, lambda, None, &opts).unwrap();
        let fi = solve(SolverKind::Fista, &p.x, &p.y, lambda, None, &opts).unwrap();
        assert!(cd.converged && fi.converged, "cd {:?} fista {:?}", cd.gap, fi.gap);
        // Same optimal value (the optimum may be non-unique in w, the
        // value is unique).
        assert_close(cd.gap.primal, fi.gap.primal, 1e-5, "objective agreement");
        // And the supports agree on clearly-nonzero weights.
        for j in 0..25 {
            if cd.w[j].abs() > 1e-3 || fi.w[j].abs() > 1e-3 {
                assert_close(cd.w[j], fi.w[j], 1e-2, &format!("w[{j}]"));
            }
        }
    }

    #[test]
    fn converges_on_sparse_text() {
        let ds = SynthSpec::text(50, 150, 49).generate();
        let p = Problem::from_dataset(&ds);
        let rep = FistaSolver::default()
            .solve(&p.x, &p.y, 0.3 * p.lambda_max(), None,
                   &SolveOptions { max_iter: 30000, ..Default::default() })
            .unwrap();
        assert!(rep.converged, "{:?}", rep.gap);
        assert!(rep.gap.rel_gap <= 1e-6);
    }
}
