//! Reduced (screened) subproblems: extract the kept feature columns,
//! solve over them, scatter the solution back to full coordinates.
//!
//! Safe screening guarantees the discarded features are zero at the
//! optimum, so `solve(reduced) ⊕ zeros = solve(full)` — exactly the
//! property the safety tests assert.

use crate::coordinator::pool::parallel_map;
use crate::data::cache::FeatureCache;
use crate::data::{csc::CscMatrix, dense::DenseMatrix, FeatureData, FeatureMatrix};
use crate::error::{Error, Result};
use crate::solver::api::{solve_with_curvature, SolveOptions, SolveReport, SolverKind};

/// Below this many kept columns a parallel gather costs more in thread
/// spawn than it saves (same rationale as the screening executor's
/// `PARALLEL_WORK_THRESHOLD`).
const PARALLEL_GATHER_MIN_COLS: usize = 512;

/// A subproblem over a subset of feature columns.
#[derive(Debug, Clone)]
pub struct ReducedProblem {
    /// Kept (original) column indices, ascending.
    pub cols: Vec<usize>,
    /// Total feature count of the parent problem.
    pub m_full: usize,
    /// The extracted feature submatrix.
    pub x: FeatureData,
    /// Per-column stats remapped from the parent cache (when built with
    /// one): serves the CD curvature vector without an O(nnz) pass.
    pub cache: Option<FeatureCache>,
}

/// Gathers the listed columns, fanning out over the pool when the kept
/// set is large. Chunks are contiguous slices of `cols` reassembled in
/// order, so the result is byte-identical to the sequential gather.
fn gather(x: &FeatureData, cols: &[usize], workers: usize) -> FeatureData {
    if workers <= 1 || cols.len() < PARALLEL_GATHER_MIN_COLS {
        return match x {
            FeatureData::Dense(d) => FeatureData::Dense(d.select_cols(cols)),
            FeatureData::Sparse(s) => FeatureData::Sparse(s.select_cols(cols)),
        };
    }
    let chunk = cols.len().div_ceil(workers * 4).max(1);
    let chunks: Vec<&[usize]> = cols.chunks(chunk).collect();
    match x {
        FeatureData::Dense(d) => {
            let parts = parallel_map(&chunks, workers, |c| d.select_cols(c));
            FeatureData::Dense(DenseMatrix::hconcat(&parts))
        }
        FeatureData::Sparse(s) => {
            let parts = parallel_map(&chunks, workers, |c| s.select_cols(c));
            FeatureData::Sparse(CscMatrix::hconcat(&parts))
        }
    }
}

impl ReducedProblem {
    /// Extracts the kept columns from `x`.
    pub fn build(x: &FeatureData, cols: Vec<usize>) -> Result<Self> {
        Self::build_with(x, cols, None, 1)
    }

    /// [`ReducedProblem::build`] with a parent [`FeatureCache`] to remap
    /// (O(|cols|) instead of an O(nnz) rebuild) and a pool-parallel
    /// column gather over `workers` threads.
    pub fn build_with(
        x: &FeatureData,
        mut cols: Vec<usize>,
        cache: Option<&FeatureCache>,
        workers: usize,
    ) -> Result<Self> {
        let m_full = x.n_features();
        cols.sort_unstable();
        cols.dedup();
        if cols.iter().any(|&j| j >= m_full) {
            return Err(Error::solver("kept column index out of range"));
        }
        let sub = gather(x, &cols, workers);
        let cache = cache.map(|c| c.select(&cols));
        Ok(ReducedProblem { cols, m_full, x: sub, cache })
    }

    /// Incremental build: when `cols` is a subset of `prev.cols` (the
    /// common case along a descending λ-grid where screening only
    /// tightens), sub-select from the previous *reduced* matrix —
    /// O(kept nnz) — instead of re-gathering from the full matrix.
    /// Falls back to [`ReducedProblem::build_with`] otherwise. Returns
    /// the problem plus whether the fast path was taken. Either way the
    /// column bytes are identical, so downstream solves are bit-identical.
    pub fn build_incremental(
        prev: &ReducedProblem,
        x: &FeatureData,
        mut cols: Vec<usize>,
        cache: Option<&FeatureCache>,
        workers: usize,
    ) -> Result<(Self, bool)> {
        cols.sort_unstable();
        cols.dedup();
        // Map each wanted column to its position in prev.cols via a
        // single merge walk (both lists ascending).
        let mut local = Vec::with_capacity(cols.len());
        let mut pi = 0usize;
        let mut subset = prev.m_full == x.n_features();
        if subset {
            for &j in &cols {
                while pi < prev.cols.len() && prev.cols[pi] < j {
                    pi += 1;
                }
                if pi < prev.cols.len() && prev.cols[pi] == j {
                    local.push(pi);
                } else {
                    subset = false;
                    break;
                }
            }
        }
        if !subset {
            return Ok((Self::build_with(x, cols, cache, workers)?, false));
        }
        let sub = gather(&prev.x, &local, workers);
        // Remap the cache from the full one when given (always O(|cols|));
        // otherwise chain from the previous reduction's cache.
        let red_cache = match (cache, &prev.cache) {
            (Some(full), _) => Some(full.select(&cols)),
            (None, Some(pc)) => Some(pc.select(&local)),
            (None, None) => None,
        };
        Ok((ReducedProblem { cols, m_full: prev.m_full, x: sub, cache: red_cache }, true))
    }

    /// Approximate bytes materialized by this problem's gather (CSC:
    /// index + value per stored entry; dense: 8 bytes per cell). Feeds
    /// the `path.gather_bytes` telemetry counter.
    pub fn gathered_bytes(&self) -> u64 {
        match &self.x {
            FeatureData::Dense(d) => (d.n_samples() * d.n_features() * 8) as u64,
            FeatureData::Sparse(s) => (s.nnz() * 12) as u64,
        }
    }

    /// Restricts a full-length warm start to the kept columns.
    pub fn restrict(&self, w_full: &[f64]) -> Vec<f64> {
        self.cols.iter().map(|&j| w_full[j]).collect()
    }

    /// Solves the reduced problem and scatters back to full length. The
    /// remapped cache (when present) supplies the CD curvature vector.
    pub fn solve(
        &self,
        kind: SolverKind,
        y: &[f64],
        lambda: f64,
        w0_full: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        let w0 = w0_full.map(|w| self.restrict(w));
        let mut rep = solve_with_curvature(
            kind,
            &self.x,
            y,
            lambda,
            w0.as_deref(),
            opts,
            self.cache.as_ref().map(|c| c.norm_sq.as_slice()),
        )?;
        rep.w = scatter_solution(self.m_full, &self.cols, &rep.w);
        Ok(rep)
    }
}

/// Places `w_reduced[k]` at full index `cols[k]`, zeros elsewhere.
pub fn scatter_solution(m_full: usize, cols: &[usize], w_reduced: &[f64]) -> Vec<f64> {
    assert_eq!(cols.len(), w_reduced.len());
    let mut w = vec![0.0; m_full];
    for (k, &j) in cols.iter().enumerate() {
        w[j] = w_reduced[k];
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::solver::api::solve;
    use crate::svm::problem::Problem;
    use crate::testkit::assert_close;

    #[test]
    fn scatter_roundtrip() {
        let w = scatter_solution(5, &[1, 3], &[2.0, -1.0]);
        assert_eq!(w, vec![0.0, 2.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn build_validates_and_dedups() {
        let ds = SynthSpec::dense(10, 5, 51).generate();
        assert!(ReducedProblem::build(&ds.x, vec![0, 7]).is_err());
        let r = ReducedProblem::build(&ds.x, vec![3, 1, 3]).unwrap();
        assert_eq!(r.cols, vec![1, 3]);
        assert_eq!(r.x.n_features(), 2);
    }

    #[test]
    fn reduced_solve_equals_full_when_dropping_inactive() {
        // Solve full; drop the provably-inactive columns; reduced solve
        // must reproduce the same solution (same objective).
        let ds = SynthSpec::dense(50, 20, 53).generate();
        let p = Problem::from_dataset(&ds);
        let lambda = 0.5 * p.lambda_max();
        let opts = SolveOptions { tol: 1e-9, max_iter: 20000, ..Default::default() };
        let full = solve(SolverKind::Cd, &p.x, &p.y, lambda, None, &opts).unwrap();
        assert!(full.converged);
        // Keep active plus a margin of near-active features.
        let theta = crate::svm::dual::theta_from_primal(&p.x, &p.y, &full.w, full.b, lambda);
        let ytheta: Vec<f64> =
            p.y.iter().zip(&theta).map(|(a, b)| a * b).collect();
        let keep: Vec<usize> = (0..p.m())
            .filter(|&j| p.x.col_dot(j, &ytheta).abs() > 0.5)
            .collect();
        assert!(keep.len() < 20, "test should actually reduce");
        let red = ReducedProblem::build(&p.x, keep).unwrap();
        let r = red.solve(SolverKind::Cd, &p.y, lambda, None, &opts).unwrap();
        assert!(r.converged);
        assert_close(r.gap.primal, full.gap.primal, 1e-6, "objective preserved");
        assert_eq!(r.w.len(), 20);
    }

    #[test]
    fn warm_start_restriction() {
        let ds = SynthSpec::dense(20, 6, 55).generate();
        let r = ReducedProblem::build(&ds.x, vec![0, 4, 5]).unwrap();
        let w_full = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(r.restrict(&w_full), vec![1.0, 5.0, 6.0]);
    }
}
