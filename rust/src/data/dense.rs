//! Column-major dense feature matrix.
//!
//! Stored feature-major (`data[j*n + i]`) because every hot loop in the
//! crate — screening bound evaluation, coordinate descent updates —
//! walks a feature column contiguously.

use super::FeatureMatrix;
use crate::error::{Error, Result};
use crate::linalg;

/// Dense `n × m` feature matrix, column(feature)-major.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    n: usize,
    m: usize,
    /// Column-major payload, length `n * m`.
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix of shape `(n, m)`.
    pub fn zeros(n: usize, m: usize) -> Self {
        DenseMatrix { n, m, data: vec![0.0; n * m] }
    }

    /// Builds from per-feature columns (each of length `n`).
    pub fn from_cols(n: usize, cols: Vec<Vec<f64>>) -> Self {
        let m = cols.len();
        let mut data = Vec::with_capacity(n * m);
        for col in &cols {
            assert_eq!(col.len(), n, "column length mismatch");
            data.extend_from_slice(col);
        }
        DenseMatrix { n, m, data }
    }

    /// Builds from a row-major buffer (sample-major, as a libsvm reader
    /// or an external tool would produce), transposing into column-major.
    pub fn from_row_major(n: usize, m: usize, rows: &[f64]) -> Result<Self> {
        if rows.len() != n * m {
            return Err(Error::data(format!(
                "row-major buffer has {} entries, expected {}",
                rows.len(),
                n * m
            )));
        }
        let mut data = vec![0.0; n * m];
        for i in 0..n {
            for j in 0..m {
                data[j * n + i] = rows[i * m + j];
            }
        }
        Ok(DenseMatrix { n, m, data })
    }

    /// Immutable view of feature column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable view of feature column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Entry accessor (row `i`, feature `j`).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    /// Entry setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Scales every feature column to unit L2 norm (zero columns kept).
    /// Returns the applied per-column scale factors.
    pub fn normalize_cols(&mut self) -> Vec<f64> {
        let mut scales = vec![1.0; self.m];
        for j in 0..self.m {
            let nrm = linalg::nrm2(self.col(j));
            if nrm > 0.0 {
                scales[j] = 1.0 / nrm;
                linalg::scale(scales[j], self.col_mut(j));
            }
        }
        scales
    }

    /// Extracts the submatrix keeping only the listed feature columns.
    pub fn select_cols(&self, cols: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.n, cols.len());
        for (jj, &j) in cols.iter().enumerate() {
            out.col_mut(jj).copy_from_slice(self.col(j));
        }
        out
    }

    /// Horizontal concatenation: stacks the columns of `parts` left to
    /// right (all parts must share the sample count). Used by the
    /// pool-parallel column gather to reassemble per-chunk selections.
    pub fn hconcat(parts: &[DenseMatrix]) -> DenseMatrix {
        let n = parts.first().map(|p| p.n).unwrap_or(0);
        let m: usize = parts.iter().map(|p| p.m).sum();
        let mut data = Vec::with_capacity(n * m);
        for p in parts {
            assert_eq!(p.n, n, "sample-count mismatch in hconcat");
            data.extend_from_slice(&p.data);
        }
        DenseMatrix { n, m, data }
    }
}

impl FeatureMatrix for DenseMatrix {
    fn n_samples(&self) -> usize {
        self.n
    }
    fn n_features(&self) -> usize {
        self.m
    }
    fn col_nnz(&self, j: usize) -> usize {
        // Dense storage stores every cell: O(1) by definition (see trait).
        let _ = j;
        self.n
    }
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        linalg::dot(self.col(j), v)
    }
    fn col_dot_seq(&self, j: usize, v: &[f64]) -> f64 {
        // In-order (non-unrolled) accumulation: must match col_dot4's
        // per-accumulator order bitwise — see the trait docs.
        let col = self.col(j);
        debug_assert_eq!(col.len(), v.len());
        let mut acc = 0.0;
        for i in 0..col.len() {
            acc += col[i] * v[i];
        }
        acc
    }
    fn col_dot4(&self, j: usize, y: &[f64], theta: &[f64]) -> (f64, f64, f64, f64) {
        linalg::dot4(self.col(j), y, theta)
    }
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        linalg::axpy(alpha, self.col(j), out);
    }
    fn col_visit(&self, j: usize, f: &mut dyn FnMut(usize, f64)) {
        for (i, &v) in self.col(j).iter().enumerate() {
            f(i, v);
        }
    }
    fn col_sqhinge_grad(&self, j: usize, y: &[f64], z: &[f64], b: f64) -> f64 {
        let col = self.col(j);
        debug_assert_eq!(col.len(), y.len());
        let mut g = 0.0;
        for i in 0..col.len() {
            let xi = (1.0 - y[i] * (z[i] + b)).max(0.0);
            g -= col[i] * y[i] * xi;
        }
        g
    }
    fn col_norm_sq(&self, j: usize) -> f64 {
        linalg::nrm2_sq(self.col(j))
    }
    fn nnz(&self) -> usize {
        // Dense storage stores every cell: O(1), not the trait's O(m) scan.
        self.n * self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_transpose() {
        // rows: s0=[1,2], s1=[3,4], s2=[5,6]
        let x = DenseMatrix::from_row_major(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(x.col(0), &[1.0, 3.0, 5.0]);
        assert_eq!(x.col(1), &[2.0, 4.0, 6.0]);
        assert_eq!(x.get(2, 1), 6.0);
    }

    #[test]
    fn row_major_length_checked() {
        assert!(DenseMatrix::from_row_major(2, 2, &[1.0]).is_err());
    }

    #[test]
    fn normalize_cols_unit_norm() {
        let mut x = DenseMatrix::from_cols(2, vec![vec![3.0, 4.0], vec![0.0, 0.0]]);
        let scales = x.normalize_cols();
        assert!((crate::linalg::nrm2(x.col(0)) - 1.0).abs() < 1e-12);
        assert_eq!(scales[1], 1.0); // zero column untouched
        assert_eq!(x.col(1), &[0.0, 0.0]);
    }

    #[test]
    fn select_cols_subset() {
        let x = DenseMatrix::from_cols(
            2,
            vec![vec![1.0, 1.0], vec![2.0, 2.0], vec![3.0, 3.0]],
        );
        let s = x.select_cols(&[2, 0]);
        assert_eq!(s.n_features(), 2);
        assert_eq!(s.col(0), &[3.0, 3.0]);
        assert_eq!(s.col(1), &[1.0, 1.0]);
    }

    #[test]
    fn hconcat_rebuilds_selection() {
        let x = DenseMatrix::from_cols(
            2,
            vec![vec![1.0, 1.0], vec![2.0, 2.0], vec![3.0, 3.0]],
        );
        let glued = DenseMatrix::hconcat(&[x.select_cols(&[0]), x.select_cols(&[1, 2])]);
        assert_eq!(glued, x);
        assert_eq!(x.nnz(), 6); // O(1) override: stored cells
    }

    #[test]
    fn feature_matrix_impl() {
        let x = DenseMatrix::from_cols(3, vec![vec![1.0, 0.0, 2.0]]);
        assert_eq!(x.col_nnz(0), 3); // stored entries, not exact nonzeros
        assert_eq!(x.col_norm_sq(0), 5.0);
        let mut out = vec![1.0; 3];
        x.col_axpy(0, 2.0, &mut out);
        assert_eq!(out, vec![3.0, 1.0, 5.0]);
    }
}
