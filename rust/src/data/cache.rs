//! Path-wide per-feature statistics cache.
//!
//! Three of the four dots the screening bound consumes — `fᵀy`, `fᵀ1`,
//! `‖f‖²` — and the per-column nnz are *λ- and θ-independent*: along a
//! regularization path (or across batched server requests) they never
//! change, yet the uncached pipeline re-derives them inside every
//! `col_dot4` sweep and every CD solve's curvature precompute.
//! [`FeatureCache`] materializes them in **one** O(nnz) pass so that:
//!
//! * screening shrinks to a single θ-dependent dot per feature
//!   ([`crate::screening::precompute::FeatureStats::from_cache`]),
//! * coordinate descent serves its curvature vector `H_j = ‖f_j‖²`
//!   straight from the cache,
//! * the block partitioner and the parallel-work threshold read nnz
//!   without re-scanning columns.
//!
//! Lifecycle: built once per [`crate::svm::problem::Problem`] (lazily,
//! on first use), then **remapped** — not recomputed — every time a
//! reduced problem selects a column subset ([`FeatureCache::select`]).
//!
//! ## Bit-identity contract
//!
//! Cached screening must be *bit-identical* to the uncached
//! `col_dot4` path (the parallel/sequential equivalence tests assert
//! exact equality). `col_dot4` accumulates its four sums in
//! independent accumulators, each in column-entry order; the cache
//! builder reproduces exactly that accumulation per statistic (via
//! [`FeatureMatrix::col_visit`], which walks entries in the same
//! order), so `dot_y`/`dot_one`/`norm_sq` match the `col_dot4`
//! accumulators to the last ulp. The remaining θ-dot uses
//! [`FeatureMatrix::col_dot_seq`], the in-order variant matching
//! `col_dot4`'s third accumulator (the unrolled `col_dot` reassociates
//! and may differ in the last ulp on dense data).

use super::FeatureMatrix;

/// Per-column λ-independent statistics for an `n × m` feature matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureCache {
    /// `f_jᵀ y` per column.
    pub dot_y: Vec<f64>,
    /// `f_jᵀ 1` (entry sum) per column.
    pub dot_one: Vec<f64>,
    /// `‖f_j‖²` per column — the CD curvature vector `H`.
    pub norm_sq: Vec<f64>,
    /// Stored entries per column (CSC column length; `n` for dense).
    pub col_nnz: Vec<usize>,
    /// Total stored entries (Σ `col_nnz`).
    pub nnz: usize,
}

impl FeatureCache {
    /// Builds the cache in one pass over the stored entries of `x`.
    pub fn build<X: FeatureMatrix>(x: &X, y: &[f64]) -> Self {
        let m = x.n_features();
        debug_assert_eq!(y.len(), x.n_samples());
        let mut dot_y = Vec::with_capacity(m);
        let mut dot_one = Vec::with_capacity(m);
        let mut norm_sq = Vec::with_capacity(m);
        let mut col_nnz = Vec::with_capacity(m);
        let mut nnz = 0usize;
        for j in 0..m {
            // Independent accumulators in entry order: bitwise the same
            // sums as col_dot4's dy/d1/qq (see module docs).
            let (mut sy, mut s1, mut sq, mut k) = (0.0f64, 0.0f64, 0.0f64, 0usize);
            x.col_visit(j, &mut |i, v| {
                sy += v * y[i];
                s1 += v;
                sq += v * v;
                k += 1;
            });
            dot_y.push(sy);
            dot_one.push(s1);
            norm_sq.push(sq);
            col_nnz.push(k);
            nnz += k;
        }
        FeatureCache { dot_y, dot_one, norm_sq, col_nnz, nnz }
    }

    /// Number of cached columns.
    pub fn len(&self) -> usize {
        self.col_nnz.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.col_nnz.is_empty()
    }

    /// Remaps the cache onto a column subset (`cols` are indices into
    /// *this* cache): the reduced-problem analogue of a fresh build,
    /// at O(|cols|) instead of O(nnz).
    pub fn select(&self, cols: &[usize]) -> FeatureCache {
        let mut out = FeatureCache {
            dot_y: Vec::with_capacity(cols.len()),
            dot_one: Vec::with_capacity(cols.len()),
            norm_sq: Vec::with_capacity(cols.len()),
            col_nnz: Vec::with_capacity(cols.len()),
            nnz: 0,
        };
        for &j in cols {
            out.dot_y.push(self.dot_y[j]);
            out.dot_one.push(self.dot_one[j]);
            out.norm_sq.push(self.norm_sq[j]);
            out.col_nnz.push(self.col_nnz[j]);
            out.nnz += self.col_nnz[j];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csc::CscMatrix;
    use crate::data::dense::DenseMatrix;
    use crate::data::synth::SynthSpec;
    use crate::data::FeatureData;

    /// The cache must reproduce `col_dot4`'s λ-independent accumulators
    /// and `nnz` exactly, on both backends.
    #[test]
    fn matches_col_dot4_bitwise() {
        for ds in [
            SynthSpec::dense(40, 30, 171).generate(),
            SynthSpec::text(60, 120, 172).generate(),
        ] {
            let cache = FeatureCache::build(&ds.x, &ds.y);
            let theta = vec![0.0; ds.n()];
            for j in 0..ds.m() {
                let (dy, d1, _, qq) = ds.x.col_dot4(j, &ds.y, &theta);
                assert_eq!(cache.dot_y[j], dy, "{} col {j} dot_y", ds.name);
                assert_eq!(cache.dot_one[j], d1, "{} col {j} dot_one", ds.name);
                assert_eq!(cache.norm_sq[j], qq, "{} col {j} norm_sq", ds.name);
                assert_eq!(cache.col_nnz[j], ds.x.col_nnz(j));
            }
            assert_eq!(cache.nnz, ds.x.nnz());
            assert_eq!(cache.len(), ds.m());
            assert!(!cache.is_empty());
        }
    }

    #[test]
    fn select_remaps() {
        let x = FeatureData::Sparse(CscMatrix::from_triplet_cols(
            3,
            vec![vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)], vec![(0, -1.0)]],
        ));
        let y = vec![1.0, -1.0, 1.0];
        let cache = FeatureCache::build(&x, &y);
        let sub = cache.select(&[2, 0]);
        assert_eq!(sub.dot_y, vec![cache.dot_y[2], cache.dot_y[0]]);
        assert_eq!(sub.norm_sq, vec![1.0, 5.0]);
        assert_eq!(sub.col_nnz, vec![1, 2]);
        assert_eq!(sub.nnz, 3);
    }

    #[test]
    fn dense_counts_stored_cells() {
        let x = DenseMatrix::from_cols(3, vec![vec![1.0, 0.0, 2.0]]);
        let cache = FeatureCache::build(&x, &[1.0, 1.0, -1.0]);
        assert_eq!(cache.col_nnz, vec![3]); // stored entries, zeros included
        assert_eq!(cache.nnz, 3);
        assert_eq!(cache.norm_sq, vec![5.0]);
        assert_eq!(cache.dot_one, vec![3.0]);
        assert_eq!(cache.dot_y, vec![-1.0]);
    }
}
