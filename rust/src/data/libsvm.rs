//! libsvm / svmlight format reader and writer.
//!
//! Format: one sample per line, `label idx:val idx:val ...` with 1-based,
//! strictly increasing feature indices. Labels are mapped to ±1 (`+1`,
//! `1`, and anything > 0 → +1; everything else → −1 must be exactly
//! parseable as a number).

use super::csc::CscMatrix;
use super::dataset::Dataset;
use super::FeatureData;
use crate::error::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parses libsvm text into a sparse [`Dataset`].
///
/// `min_features` lets callers force a dimensionality larger than the
/// max index present (0 = infer from data).
pub fn parse_reader<R: BufRead>(name: &str, reader: R, min_features: usize) -> Result<Dataset> {
    let mut y = Vec::new();
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::new();
    let mut max_feature = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().ok_or_else(|| Error::data("empty line"))?;
        let label: f64 = label_tok
            .parse()
            .map_err(|_| Error::data(format!("line {}: bad label {label_tok:?}", lineno + 1)))?;
        y.push(if label > 0.0 { 1.0 } else { -1.0 });

        let mut entries = Vec::new();
        let mut prev_idx = 0usize;
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .ok_or_else(|| Error::data(format!("line {}: bad pair {tok:?}", lineno + 1)))?;
            let idx: usize = idx_s
                .parse()
                .map_err(|_| Error::data(format!("line {}: bad index {idx_s:?}", lineno + 1)))?;
            let val: f64 = val_s
                .parse()
                .map_err(|_| Error::data(format!("line {}: bad value {val_s:?}", lineno + 1)))?;
            if idx == 0 {
                return Err(Error::data(format!("line {}: indices are 1-based", lineno + 1)));
            }
            if idx <= prev_idx {
                return Err(Error::data(format!(
                    "line {}: indices must be strictly increasing",
                    lineno + 1
                )));
            }
            prev_idx = idx;
            max_feature = max_feature.max(idx);
            if val != 0.0 {
                entries.push((idx as u32 - 1, val));
            }
        }
        rows.push(entries);
    }

    let n = y.len();
    let m = max_feature.max(min_features);
    if n == 0 {
        return Err(Error::data("no samples in input"));
    }
    // Transpose row-wise triplets into column-wise.
    let mut cols: Vec<Vec<(u32, f64)>> = vec![Vec::new(); m];
    for (i, row) in rows.into_iter().enumerate() {
        for (j, v) in row {
            cols[j as usize].push((i as u32, v));
        }
    }
    let x = CscMatrix::from_triplet_cols(n, cols);
    Dataset::try_new(name, FeatureData::Sparse(x), y)
}

/// Loads a libsvm file from disk.
pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    let file = std::fs::File::open(path)?;
    parse_reader(&name, BufReader::new(file), 0)
}

/// Writes a dataset in libsvm format.
pub fn save(ds: &Dataset, mut w: impl Write) -> Result<()> {
    use super::FeatureMatrix;
    let n = ds.n();
    let m = ds.m();
    // Gather row-wise views: walk every column once, bucket by row.
    let mut rows: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    let mut buf = vec![0.0; n];
    for j in 0..m {
        ds.x.densify_col(j, &mut buf);
        for (i, &v) in buf.iter().enumerate() {
            if v != 0.0 {
                rows[i].push((j + 1, v));
            }
        }
    }
    for (i, row) in rows.iter().enumerate() {
        let label = if ds.y[i] > 0.0 { "+1" } else { "-1" };
        write!(w, "{label}")?;
        for (j, v) in row {
            write!(w, " {j}:{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::FeatureMatrix;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.25
-1 2:2.0
+1 1:1.0 2:-1.0 3:0.5  # trailing comment
";

    #[test]
    fn parse_basic() {
        let ds = parse_reader("t", SAMPLE.as_bytes(), 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.m(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.col_nnz(0), 2);
        assert_eq!(ds.x.col_dot(2, &[1.0, 1.0, 1.0]), 1.75);
    }

    #[test]
    fn parse_min_features_pads() {
        let ds = parse_reader("t", SAMPLE.as_bytes(), 10).unwrap();
        assert_eq!(ds.m(), 10);
        assert_eq!(ds.x.col_nnz(9), 0);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(parse_reader("t", "+1 0:1.0".as_bytes(), 0).is_err());
    }

    #[test]
    fn parse_rejects_unsorted() {
        assert!(parse_reader("t", "+1 3:1.0 2:1.0".as_bytes(), 0).is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_reader("t", "abc 1:1.0".as_bytes(), 0).is_err());
        assert!(parse_reader("t", "+1 1:xyz".as_bytes(), 0).is_err());
        assert!(parse_reader("t", "+1 1-2".as_bytes(), 0).is_err());
        assert!(parse_reader("t", "".as_bytes(), 0).is_err());
    }

    #[test]
    fn roundtrip() {
        let ds = parse_reader("t", SAMPLE.as_bytes(), 0).unwrap();
        let mut out = Vec::new();
        save(&ds, &mut out).unwrap();
        let ds2 = parse_reader("t2", out.as_slice(), 0).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds.x.nnz(), ds2.x.nnz());
        for j in 0..ds.m() {
            let v = vec![1.0; ds.n()];
            assert!((ds.x.col_dot(j, &v) - ds2.x.col_dot(j, &v)).abs() < 1e-12);
        }
    }

    #[test]
    fn roundtrip_synthetic() {
        let ds = crate::data::synth::SynthSpec::text(30, 100, 3).generate();
        let mut out = Vec::new();
        save(&ds, &mut out).unwrap();
        let ds2 = parse_reader("re", out.as_slice(), ds.m()).unwrap();
        assert_eq!(ds2.n(), ds.n());
        assert_eq!(ds2.m(), ds.m());
        assert_eq!(ds2.y, ds.y);
        assert_eq!(ds2.x.nnz(), ds.x.nnz());
    }
}
