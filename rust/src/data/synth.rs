//! Deterministic synthetic dataset generators + the PCG random substrate.
//!
//! The image is offline, so the evaluation runs on synthetic replicas of
//! the dataset regimes safe-screening papers evaluate on (DESIGN.md §4):
//!
//! * [`SynthSpec::dense`] — Gaussian features with a planted sparse
//!   hyperplane (UCI-dense regime, e.g. *magic04*-like).
//! * [`SynthSpec::text`] — Zipf-distributed sparse bag-of-words with a
//!   sparse topic model (rcv1/news20 regime).
//! * [`SynthSpec::corr`] — groups of strongly correlated features
//!   (microarray regime), the stress case for screening because
//!   near-duplicate features have near-identical bounds.
//!
//! All generators are deterministic functions of their seed.

use super::csc::CscMatrix;
use super::dataset::Dataset;
use super::dense::DenseMatrix;
use super::{FeatureData, FeatureMatrix};

/// PCG-XSH-RR 64/32 pseudo-random generator (O'Neill 2014).
///
/// Small, fast, reproducible across platforms; the crate's only source of
/// randomness (the vendored crate set has no `rand`).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6364136223846793005;

    /// Creates a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 54 (arbitrary fixed odd inc).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Next 32 uniform random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Samples `k` distinct indices from `[0, n)`.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in n - k..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

/// Zipf sampler over `[0, n)` with exponent `s`, via precomputed CDF and
/// binary search. Deterministic given the RNG.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Builds the sampler (O(n) setup).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draws one rank in `[0, n)` (0 = most frequent).
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Which generator family to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// Dense Gaussian features, planted sparse hyperplane.
    Dense,
    /// Sparse Zipf bag-of-words, sparse topic weights.
    Text,
    /// Correlated feature groups (dense), planted group-sparse weights.
    Corr,
}

impl SynthKind {
    /// Parses `"dense" | "text" | "corr"`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dense" => Some(SynthKind::Dense),
            "text" => Some(SynthKind::Text),
            "corr" => Some(SynthKind::Corr),
            _ => None,
        }
    }
}

/// Full specification of a synthetic dataset; `generate()` is a pure
/// function of this struct.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Generator family.
    pub kind: SynthKind,
    /// Number of samples.
    pub n: usize,
    /// Number of features.
    pub m: usize,
    /// RNG seed.
    pub seed: u64,
    /// Number of truly informative features.
    pub k_informative: usize,
    /// Label noise: probability of flipping a label.
    pub flip_prob: f64,
    /// Text: mean tokens per document.
    pub doc_len: usize,
    /// Text: Zipf exponent.
    pub zipf_s: f64,
    /// Corr: features per correlated group.
    pub group_size: usize,
    /// Corr: within-group correlation strength in [0,1).
    pub group_rho: f64,
    /// Normalize feature columns to unit L2 norm (standard for screening).
    pub normalize: bool,
}

impl SynthSpec {
    /// Dense Gaussian spec with sensible defaults.
    pub fn dense(n: usize, m: usize, seed: u64) -> Self {
        SynthSpec {
            kind: SynthKind::Dense,
            n,
            m,
            seed,
            k_informative: (m / 20).clamp(2, 50),
            flip_prob: 0.05,
            doc_len: 0,
            zipf_s: 0.0,
            group_size: 0,
            group_rho: 0.0,
            normalize: true,
        }
    }

    /// Sparse text-like spec with sensible defaults.
    pub fn text(n: usize, m: usize, seed: u64) -> Self {
        SynthSpec {
            kind: SynthKind::Text,
            n,
            m,
            seed,
            k_informative: (m / 50).clamp(5, 200),
            flip_prob: 0.03,
            doc_len: 60,
            zipf_s: 1.05,
            group_size: 0,
            group_rho: 0.0,
            normalize: true,
        }
    }

    /// Correlated-groups spec with sensible defaults.
    pub fn corr(n: usize, m: usize, seed: u64) -> Self {
        SynthSpec {
            kind: SynthKind::Corr,
            n,
            m,
            seed,
            k_informative: (m / 25).clamp(2, 40),
            flip_prob: 0.05,
            doc_len: 0,
            zipf_s: 0.0,
            group_size: 10,
            group_rho: 0.9,
            normalize: true,
        }
    }

    /// Canonical name used in reports: e.g. `synth-text-n2000-m20000`.
    pub fn name(&self) -> String {
        let kind = match self.kind {
            SynthKind::Dense => "dense",
            SynthKind::Text => "text",
            SynthKind::Corr => "corr",
        };
        format!("synth-{kind}-n{}-m{}", self.n, self.m)
    }

    /// Generates the dataset.
    pub fn generate(&self) -> Dataset {
        match self.kind {
            SynthKind::Dense => gen_dense(self),
            SynthKind::Text => gen_text(self),
            SynthKind::Corr => gen_corr(self),
        }
    }
}

/// Labels from a planted sparse linear model + bias, with flip noise.
/// Ensures both classes are non-empty by construction (flips one sample
/// if the draw came out single-class).
fn assign_labels(
    rng: &mut Pcg32,
    scores: &[f64],
    flip_prob: f64,
) -> Vec<f64> {
    let n = scores.len();
    let mut y: Vec<f64> = scores
        .iter()
        .map(|s| if *s >= 0.0 { 1.0 } else { -1.0 })
        .collect();
    for yi in y.iter_mut() {
        if rng.f64() < flip_prob {
            *yi = -*yi;
        }
    }
    let pos = y.iter().filter(|v| **v > 0.0).count();
    if pos == 0 {
        y[0] = 1.0;
    } else if pos == n {
        y[0] = -1.0;
    }
    y
}

fn planted_weights(rng: &mut Pcg32, m: usize, k: usize) -> Vec<(usize, f64)> {
    let idx = rng.sample_distinct(m, k.min(m));
    idx.into_iter()
        .map(|j| {
            let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
            (j, sign * (0.5 + rng.f64()))
        })
        .collect()
}

fn gen_dense(spec: &SynthSpec) -> Dataset {
    let mut rng = Pcg32::seeded(spec.seed);
    let mut x = DenseMatrix::zeros(spec.n, spec.m);
    for j in 0..spec.m {
        let col = x.col_mut(j);
        for v in col.iter_mut() {
            *v = rng.gaussian();
        }
    }
    let w_true = planted_weights(&mut rng, spec.m, spec.k_informative);
    let mut scores = vec![0.0; spec.n];
    for &(j, wj) in &w_true {
        x.col_axpy(j, wj, &mut scores);
    }
    let bias = 0.3 * rng.gaussian();
    for s in scores.iter_mut() {
        *s += bias + 0.1 * rng.gaussian();
    }
    let y = assign_labels(&mut rng, &scores, spec.flip_prob);
    if spec.normalize {
        x.normalize_cols();
    }
    Dataset::new(spec.name(), FeatureData::Dense(x), y)
        .with_true_support(w_true.iter().map(|e| e.0).collect())
}

fn gen_text(spec: &SynthSpec) -> Dataset {
    let mut rng = Pcg32::seeded(spec.seed);
    let zipf = Zipf::new(spec.m, spec.zipf_s);
    // Random permutation so informative features aren't all high-frequency.
    let mut perm: Vec<usize> = (0..spec.m).collect();
    rng.shuffle(&mut perm);

    let mut cols: Vec<Vec<(u32, f64)>> = vec![Vec::new(); spec.m];
    for i in 0..spec.n {
        // Document length ~ doc_len ± 50%.
        let len = (spec.doc_len as f64 * (0.5 + rng.f64())).max(1.0) as usize;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..len {
            let rank = zipf.sample(&mut rng);
            *counts.entry(perm[rank]).or_insert(0.0) += 1.0;
        }
        for (j, c) in counts {
            // log-scaled term frequency, the usual tf transform
            cols[j].push((i as u32, 1.0 + (c as f64).ln()));
        }
    }
    let mut x = CscMatrix::from_triplet_cols(spec.n, cols);

    // Informative features drawn from the *frequent* half so they appear
    // in enough documents to matter.
    let mut w_true = Vec::new();
    {
        let mut candidates: Vec<usize> = (0..spec.m)
            .filter(|&j| x.col_nnz(j) >= spec.n / 50)
            .collect();
        if candidates.is_empty() {
            candidates = (0..spec.m).collect();
        }
        rng.shuffle(&mut candidates);
        for &j in candidates.iter().take(spec.k_informative) {
            let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
            w_true.push((j, sign * (0.5 + rng.f64())));
        }
    }
    let mut scores = vec![0.0; spec.n];
    for &(j, wj) in &w_true {
        x.col_axpy(j, wj, &mut scores);
    }
    // Center scores so classes are roughly balanced.
    let mean = crate::linalg::sum(&scores) / spec.n as f64;
    for s in scores.iter_mut() {
        *s -= mean;
    }
    let y = assign_labels(&mut rng, &scores, spec.flip_prob);
    if spec.normalize {
        x.normalize_cols();
    }
    Dataset::new(spec.name(), FeatureData::Sparse(x), y)
        .with_true_support(w_true.iter().map(|e| e.0).collect())
}

fn gen_corr(spec: &SynthSpec) -> Dataset {
    let mut rng = Pcg32::seeded(spec.seed);
    let gsize = spec.group_size.max(1);
    let n_groups = spec.m.div_ceil(gsize);
    let mut x = DenseMatrix::zeros(spec.n, spec.m);
    // Shared factor per group + idiosyncratic noise:
    // f = sqrt(rho) * g + sqrt(1-rho) * e
    let rho = spec.group_rho.clamp(0.0, 0.999);
    let (a, b) = (rho.sqrt(), (1.0 - rho).sqrt());
    let mut factor = vec![0.0; spec.n];
    for g in 0..n_groups {
        for v in factor.iter_mut() {
            *v = rng.gaussian();
        }
        for j in g * gsize..((g + 1) * gsize).min(spec.m) {
            let col = x.col_mut(j);
            for (i, v) in col.iter_mut().enumerate() {
                *v = a * factor[i] + b * rng.gaussian();
            }
        }
    }
    let w_true = planted_weights(&mut rng, spec.m, spec.k_informative);
    let mut scores = vec![0.0; spec.n];
    for &(j, wj) in &w_true {
        x.col_axpy(j, wj, &mut scores);
    }
    let y = assign_labels(&mut rng, &scores, spec.flip_prob);
    if spec.normalize {
        x.normalize_cols();
    }
    Dataset::new(spec.name(), FeatureData::Dense(x), y)
        .with_true_support(w_true.iter().map(|e| e.0).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_reference_stream_is_deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg32::seeded(1);
        let mut mean = 0.0;
        for _ in 0..10_000 {
            let u = rng.f64();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "uniform mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(2);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "gaussian var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut rng = Pcg32::seeded(3);
        let s = rng.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::BTreeSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg32::seeded(4);
        let z = Zipf::new(1000, 1.1);
        let mut low = 0;
        for _ in 0..5000 {
            let k = z.sample(&mut rng);
            assert!(k < 1000);
            if k < 50 {
                low += 1;
            }
        }
        // top-5% ranks should absorb a large share of the mass
        assert!(low > 1500, "zipf not skewed: {low}");
    }

    #[test]
    fn generators_are_deterministic() {
        for spec in [
            SynthSpec::dense(40, 30, 9),
            SynthSpec::text(40, 60, 9),
            SynthSpec::corr(40, 30, 9),
        ] {
            let d1 = spec.generate();
            let d2 = spec.generate();
            assert_eq!(d1.y, d2.y, "{}", spec.name());
            assert_eq!(d1.x.nnz(), d2.x.nnz());
        }
    }

    #[test]
    fn generated_shapes_and_labels() {
        let ds = SynthSpec::text(50, 200, 11).generate();
        assert_eq!(ds.x.n_samples(), 50);
        assert_eq!(ds.x.n_features(), 200);
        assert_eq!(ds.y.len(), 50);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(ds.n_pos() > 0 && ds.n_neg() > 0);
    }

    #[test]
    fn normalization_applied() {
        let ds = SynthSpec::dense(30, 10, 5).generate();
        for j in 0..10 {
            let nsq = ds.x.col_norm_sq(j);
            assert!((nsq - 1.0).abs() < 1e-9, "col {j} norm² {nsq}");
        }
    }

    #[test]
    fn text_is_sparse() {
        let ds = SynthSpec::text(100, 2000, 6).generate();
        assert!(ds.x.density() < 0.05, "density {}", ds.x.density());
    }

    #[test]
    fn corr_groups_are_correlated() {
        let spec = SynthSpec::corr(500, 20, 13);
        let ds = spec.generate();
        // Features 0 and 1 share a group factor with rho=0.9.
        let mut f0 = vec![0.0; 500];
        let mut f1 = vec![0.0; 500];
        ds.x.densify_col(0, &mut f0);
        ds.x.densify_col(1, &mut f1);
        let corr = crate::linalg::dot(&f0, &f1)
            / (crate::linalg::nrm2(&f0) * crate::linalg::nrm2(&f1));
        assert!(corr > 0.7, "in-group correlation {corr}");
        // Feature 0 and one from another group: weak.
        let mut g = vec![0.0; 500];
        ds.x.densify_col(15, &mut g);
        let cross =
            crate::linalg::dot(&f0, &g) / (crate::linalg::nrm2(&f0) * crate::linalg::nrm2(&g));
        assert!(cross.abs() < 0.3, "cross-group correlation {cross}");
    }
}
