//! Dataset container: features ⊕ labels ⊕ metadata.

use super::{FeatureData, FeatureMatrix};
use crate::error::{Error, Result};

/// A binary-classification dataset in the paper's convention:
/// `x` is n×m (samples × features), `y ∈ {−1,+1}ⁿ`.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Human-readable dataset name (used in reports).
    pub name: String,
    /// Feature matrix.
    pub x: FeatureData,
    /// Labels, each ±1.
    pub y: Vec<f64>,
    /// Indices of the planted informative features, when known
    /// (synthetic data only; used by recovery diagnostics).
    pub true_support: Option<Vec<usize>>,
}

impl Dataset {
    /// Creates a dataset, validating labels and shapes.
    pub fn new(name: impl Into<String>, x: FeatureData, y: Vec<f64>) -> Self {
        let ds = Dataset { name: name.into(), x, y, true_support: None };
        ds.validate().expect("invalid dataset");
        ds
    }

    /// Fallible constructor for untrusted inputs (e.g. file loads).
    pub fn try_new(name: impl Into<String>, x: FeatureData, y: Vec<f64>) -> Result<Self> {
        let ds = Dataset { name: name.into(), x, y, true_support: None };
        ds.validate()?;
        Ok(ds)
    }

    /// Attaches the planted support (builder style).
    pub fn with_true_support(mut self, support: Vec<usize>) -> Self {
        self.true_support = Some(support);
        self
    }

    fn validate(&self) -> Result<()> {
        if self.y.len() != self.x.n_samples() {
            return Err(Error::data(format!(
                "labels ({}) != samples ({})",
                self.y.len(),
                self.x.n_samples()
            )));
        }
        if self.y.iter().any(|&v| v != 1.0 && v != -1.0) {
            return Err(Error::data("labels must be ±1"));
        }
        if self.y.is_empty() {
            return Err(Error::data("empty dataset"));
        }
        Ok(())
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.x.n_samples()
    }

    /// Number of features.
    pub fn m(&self) -> usize {
        self.x.n_features()
    }

    /// Count of positive labels.
    pub fn n_pos(&self) -> usize {
        self.y.iter().filter(|v| **v > 0.0).count()
    }

    /// Count of negative labels.
    pub fn n_neg(&self) -> usize {
        self.y.len() - self.n_pos()
    }

    /// One-line description for logs.
    pub fn describe(&self) -> String {
        format!(
            "{}: n={} m={} nnz={} density={:.4} (+{} / -{})",
            self.name,
            self.n(),
            self.m(),
            self.x.nnz(),
            self.x.density(),
            self.n_pos(),
            self.n_neg()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    fn xy() -> (FeatureData, Vec<f64>) {
        let x = DenseMatrix::from_cols(3, vec![vec![1.0, 2.0, 3.0]]);
        (FeatureData::Dense(x), vec![1.0, -1.0, 1.0])
    }

    #[test]
    fn construction_and_counts() {
        let (x, y) = xy();
        let ds = Dataset::new("toy", x, y);
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.m(), 1);
        assert_eq!(ds.n_pos(), 2);
        assert_eq!(ds.n_neg(), 1);
        assert!(ds.describe().contains("toy"));
    }

    #[test]
    fn rejects_bad_labels() {
        let (x, _) = xy();
        assert!(Dataset::try_new("bad", x, vec![1.0, 0.5, -1.0]).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let (x, _) = xy();
        assert!(Dataset::try_new("bad", x, vec![1.0, -1.0]).is_err());
    }
}
