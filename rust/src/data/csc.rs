//! Compressed-sparse-column feature matrix.
//!
//! The natural layout for the paper's algorithms on text-like data:
//! screening walks feature columns (`f̂ᵀθ₁` accelerated "by utilizing the
//! sparse structure", §6.4 of the paper), and coordinate descent updates
//! one feature column at a time.

use super::FeatureMatrix;
use crate::error::{Error, Result};

/// CSC sparse `n × m` feature matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    n: usize,
    m: usize,
    /// Column pointers, length `m + 1`.
    indptr: Vec<usize>,
    /// Row (sample) indices, length nnz, strictly increasing per column.
    indices: Vec<u32>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl CscMatrix {
    /// Builds from raw CSC arrays, validating the invariants.
    pub fn new(
        n: usize,
        m: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Self> {
        if indptr.len() != m + 1 {
            return Err(Error::data("indptr length must be m+1"));
        }
        if indptr[0] != 0 || *indptr.last().unwrap() != indices.len() {
            return Err(Error::data("indptr must start at 0 and end at nnz"));
        }
        if indices.len() != values.len() {
            return Err(Error::data("indices/values length mismatch"));
        }
        for j in 0..m {
            if indptr[j] > indptr[j + 1] {
                return Err(Error::data(format!("indptr not monotone at column {j}")));
            }
            let mut prev: i64 = -1;
            for k in indptr[j]..indptr[j + 1] {
                let i = indices[k] as i64;
                if i <= prev {
                    return Err(Error::data(format!(
                        "row indices not strictly increasing in column {j}"
                    )));
                }
                if i as usize >= n {
                    return Err(Error::data(format!("row index {i} out of range in column {j}")));
                }
                prev = i;
            }
        }
        Ok(CscMatrix { n, m, indptr, indices, values })
    }

    /// Builds from per-column `(row, value)` triplet lists (rows need not
    /// be sorted; duplicates are summed).
    pub fn from_triplet_cols(n: usize, cols: Vec<Vec<(u32, f64)>>) -> Self {
        let m = cols.len();
        let mut indptr = Vec::with_capacity(m + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for mut col in cols {
            col.sort_by_key(|e| e.0);
            let mut k = 0;
            while k < col.len() {
                let (row, mut val) = col[k];
                let mut k2 = k + 1;
                while k2 < col.len() && col[k2].0 == row {
                    val += col[k2].1;
                    k2 += 1;
                }
                if val != 0.0 {
                    assert!((row as usize) < n, "row index out of range");
                    indices.push(row);
                    values.push(val);
                }
                k = k2;
            }
            indptr.push(indices.len());
        }
        CscMatrix { n, m, indptr, indices, values }
    }

    /// Converts a dense column-major matrix, dropping exact zeros.
    pub fn from_dense(x: &super::dense::DenseMatrix) -> Self {
        let n = x.n_samples();
        let m = x.n_features();
        let mut cols = Vec::with_capacity(m);
        for j in 0..m {
            let col: Vec<(u32, f64)> = x
                .col(j)
                .iter()
                .enumerate()
                .filter(|(_, v)| **v != 0.0)
                .map(|(i, v)| (i as u32, *v))
                .collect();
            cols.push(col);
        }
        CscMatrix::from_triplet_cols(n, cols)
    }

    /// Sparse view of feature column `j`: `(row_indices, values)`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Extracts the submatrix keeping only the listed feature columns.
    ///
    /// Direct slice copies: source columns are already sorted and
    /// deduplicated, so no triplet round-trip is needed — this is the
    /// per-step gather of the path runner and must cost O(copied nnz).
    pub fn select_cols(&self, cols: &[usize]) -> CscMatrix {
        let total: usize = cols.iter().map(|&j| self.indptr[j + 1] - self.indptr[j]).sum();
        let mut indptr = Vec::with_capacity(cols.len() + 1);
        let mut indices = Vec::with_capacity(total);
        let mut values = Vec::with_capacity(total);
        indptr.push(0);
        for &j in cols {
            let (idx, val) = self.col(j);
            indices.extend_from_slice(idx);
            values.extend_from_slice(val);
            indptr.push(indices.len());
        }
        CscMatrix { n: self.n, m: cols.len(), indptr, indices, values }
    }

    /// Horizontal concatenation of column-wise pieces sharing `n`. Used
    /// by the pool-parallel gather to reassemble per-chunk selections.
    pub fn hconcat(parts: &[CscMatrix]) -> CscMatrix {
        let n = parts.first().map(|p| p.n).unwrap_or(0);
        let m: usize = parts.iter().map(|p| p.m).sum();
        let total: usize = parts.iter().map(|p| p.values.len()).sum();
        let mut indptr = Vec::with_capacity(m + 1);
        let mut indices = Vec::with_capacity(total);
        let mut values = Vec::with_capacity(total);
        indptr.push(0);
        for p in parts {
            assert_eq!(p.n, n, "sample-count mismatch in hconcat");
            let base = indices.len();
            indices.extend_from_slice(&p.indices);
            values.extend_from_slice(&p.values);
            indptr.extend(p.indptr[1..].iter().map(|k| base + k));
        }
        CscMatrix { n, m, indptr, indices, values }
    }

    /// Scales every column to unit L2 norm; returns the scale factors.
    pub fn normalize_cols(&mut self) -> Vec<f64> {
        let mut scales = vec![1.0; self.m];
        for j in 0..self.m {
            let (lo, hi) = (self.indptr[j], self.indptr[j + 1]);
            let nrm: f64 = self.values[lo..hi].iter().map(|v| v * v).sum::<f64>().sqrt();
            if nrm > 0.0 {
                scales[j] = 1.0 / nrm;
                for v in &mut self.values[lo..hi] {
                    *v *= scales[j];
                }
            }
        }
        scales
    }
}

impl FeatureMatrix for CscMatrix {
    fn n_samples(&self) -> usize {
        self.n
    }
    fn n_features(&self) -> usize {
        self.m
    }
    fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.n);
        let (idx, val) = self.col(j);
        let mut acc = 0.0;
        for (i, x) in idx.iter().zip(val) {
            acc += x * v[*i as usize];
        }
        acc
    }
    fn col_dot_seq(&self, j: usize, v: &[f64]) -> f64 {
        // CSC col_dot is already in-order; repeated here to skip the
        // trait default's dyn-dispatch col_visit on the hot θ-dot.
        self.col_dot(j, v)
    }
    fn col_dot4(&self, j: usize, y: &[f64], theta: &[f64]) -> (f64, f64, f64, f64) {
        debug_assert_eq!(y.len(), self.n);
        debug_assert_eq!(theta.len(), self.n);
        let (idx, val) = self.col(j);
        let (mut dy, mut d1, mut dt, mut qq) = (0.0, 0.0, 0.0, 0.0);
        for (i, x) in idx.iter().zip(val) {
            let i = *i as usize;
            dy += x * y[i];
            d1 += x;
            dt += x * theta[i];
            qq += x * x;
        }
        (dy, d1, dt, qq)
    }
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.n);
        let (idx, val) = self.col(j);
        for (i, x) in idx.iter().zip(val) {
            out[*i as usize] += alpha * x;
        }
    }
    fn col_norm_sq(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        val.iter().map(|v| v * v).sum()
    }
    fn col_visit(&self, j: usize, f: &mut dyn FnMut(usize, f64)) {
        let (idx, val) = self.col(j);
        for (i, v) in idx.iter().zip(val) {
            f(*i as usize, *v);
        }
    }
    fn col_sqhinge_grad(&self, j: usize, y: &[f64], z: &[f64], b: f64) -> f64 {
        let (idx, val) = self.col(j);
        let mut g = 0.0;
        for (i, v) in idx.iter().zip(val) {
            let i = *i as usize;
            let xi = (1.0 - y[i] * (z[i] + b)).max(0.0);
            g -= v * y[i] * xi;
        }
        g
    }
    fn nnz(&self) -> usize {
        self.values.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    fn toy() -> CscMatrix {
        // f0 = [1,0,2], f1 = [0,3,0]
        CscMatrix::from_triplet_cols(3, vec![vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)]])
    }

    #[test]
    fn construction_and_access() {
        let x = toy();
        assert_eq!(x.n_samples(), 3);
        assert_eq!(x.n_features(), 2);
        assert_eq!(x.col_nnz(0), 2);
        let (idx, val) = x.col(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(val, &[1.0, 2.0]);
    }

    #[test]
    fn triplets_sum_duplicates_and_drop_zeros() {
        let x = CscMatrix::from_triplet_cols(
            2,
            vec![vec![(0, 1.0), (0, 2.0), (1, 3.0), (1, -3.0)]],
        );
        let (idx, val) = x.col(0);
        assert_eq!(idx, &[0]);
        assert_eq!(val, &[3.0]);
    }

    #[test]
    fn validation_rejects_bad_indptr() {
        assert!(CscMatrix::new(2, 1, vec![0, 2], vec![0], vec![1.0]).is_err());
        assert!(CscMatrix::new(2, 1, vec![1, 1], vec![], vec![]).is_err());
        // unsorted rows
        assert!(CscMatrix::new(3, 1, vec![0, 2], vec![2, 0], vec![1.0, 1.0]).is_err());
        // out-of-range row
        assert!(CscMatrix::new(2, 1, vec![0, 1], vec![5], vec![1.0]).is_err());
    }

    #[test]
    fn dot_matches_dense() {
        let x = toy();
        let d = DenseMatrix::from_cols(3, vec![vec![1.0, 0.0, 2.0], vec![0.0, 3.0, 0.0]]);
        let v = vec![0.5, -1.0, 2.0];
        let th = vec![1.0, 1.0, -1.0];
        for j in 0..2 {
            assert_eq!(x.col_dot(j, &v), d.col_dot(j, &v));
            assert_eq!(x.col_dot4(j, &v, &th), d.col_dot4(j, &v, &th));
            assert_eq!(x.col_norm_sq(j), d.col_norm_sq(j));
        }
    }

    #[test]
    fn from_dense_roundtrip() {
        let d = DenseMatrix::from_cols(3, vec![vec![1.0, 0.0, 2.0], vec![0.0, 3.0, 0.0]]);
        let x = CscMatrix::from_dense(&d);
        assert_eq!(x, toy());
    }

    #[test]
    fn axpy_scatter() {
        let x = toy();
        let mut out = vec![0.0; 3];
        x.col_axpy(0, 2.0, &mut out);
        assert_eq!(out, vec![2.0, 0.0, 4.0]);
    }

    #[test]
    fn normalize_and_select() {
        let mut x = toy();
        let s = x.normalize_cols();
        assert!((x.col_norm_sq(0) - 1.0).abs() < 1e-12);
        assert!((s[0] - 1.0 / 5.0f64.sqrt()).abs() < 1e-12);
        let sub = x.select_cols(&[1]);
        assert_eq!(sub.n_features(), 1);
        assert!((sub.col_norm_sq(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hconcat_rebuilds_selection() {
        let x = CscMatrix::from_triplet_cols(
            3,
            vec![
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![],
                vec![(0, -1.0), (1, 4.0)],
            ],
        );
        let whole = x.select_cols(&[0, 1, 2, 3]);
        let glued = CscMatrix::hconcat(&[x.select_cols(&[0, 1]), x.select_cols(&[2, 3])]);
        assert_eq!(glued, whole);
        assert_eq!(glued, x);
        assert_eq!(CscMatrix::hconcat(&[]).n_features(), 0);
    }

    #[test]
    fn nnz_is_total_stored() {
        let x = toy();
        assert_eq!(x.nnz(), 3);
        assert_eq!(x.select_cols(&[0]).nnz(), 2);
    }
}
