//! Data substrate: feature matrices (dense + CSC sparse), dataset
//! container, libsvm-format I/O and deterministic synthetic generators.
//!
//! Throughout the crate the data matrix follows the paper's convention:
//! `X` holds `n` samples with `m` features; features are *columns*
//! (`f_j ∈ ℝⁿ`). Screening and coordinate descent are feature-column
//! algorithms, so both backends are optimized for fast column access:
//! [`dense::DenseMatrix`] stores column-major, [`csc::CscMatrix`] is
//! compressed-sparse-column.

pub mod cache;
pub mod csc;
pub mod dataset;
pub mod dense;
pub mod libsvm;
pub mod synth;

pub use cache::FeatureCache;

/// Column-oriented access to a feature matrix (n samples × m features).
///
/// All screening/solver code is generic over this trait, so dense and
/// sparse datasets share one implementation of the paper's algorithms.
pub trait FeatureMatrix {
    /// Number of samples (rows), `n` in the paper.
    fn n_samples(&self) -> usize;
    /// Number of features (columns), `m` in the paper.
    fn n_features(&self) -> usize;
    /// **Stored** entries in feature column `j` — O(1) for both
    /// backends: the CSC column length, or `n` for dense storage (which
    /// stores every cell, zeros included). Used as the work estimate by
    /// the block partitioner; exact zero-counting would itself cost a
    /// full data pass (Perf §P5).
    fn col_nnz(&self, j: usize) -> usize;

    /// Dot product of feature column `j` with a dense vector `v` (len n).
    fn col_dot(&self, j: usize, v: &[f64]) -> f64;

    /// Like [`FeatureMatrix::col_dot`], but with strictly *in-order*
    /// accumulation — bitwise-matching the corresponding accumulator of
    /// [`FeatureMatrix::col_dot4`]. Cached screening
    /// ([`cache::FeatureCache`]) relies on this exact-match guarantee;
    /// plain `col_dot` may reassociate (the dense backend unrolls
    /// 4-way) and differ in the last ulp.
    fn col_dot_seq(&self, j: usize, v: &[f64]) -> f64 {
        let mut acc = 0.0;
        self.col_visit(j, &mut |i, x| acc += x * v[i]);
        acc
    }

    /// The per-feature statistics panel in one pass:
    /// `(f_jᵀ y, f_jᵀ 1, f_jᵀ theta, ‖f_j‖²)`.
    ///
    /// This is the native analogue of the Pallas panel matmul and the
    /// single O(nnz) operation the screening bound needs per feature.
    fn col_dot4(&self, j: usize, y: &[f64], theta: &[f64]) -> (f64, f64, f64, f64);

    /// `out += alpha * f_j` (dense accumulator, len n).
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]);

    /// Visits the stored entries of column `j` as `(row, value)` pairs.
    ///
    /// Dense backends visit every row; sparse backends only non-zeros.
    fn col_visit(&self, j: usize, f: &mut dyn FnMut(usize, f64));

    /// Fused coordinate-descent gradient for the squared hinge:
    /// `g_j = −Σ_{i ∈ supp(f_j)} x_ij · y_i · max(0, 1 − y_i(z_i + b))`.
    ///
    /// This is THE inner loop of the CD solver; the default goes through
    /// the dynamic [`FeatureMatrix::col_visit`], but both backends
    /// override it with direct loops (25% of solve time was dyn-dispatch
    /// overhead — EXPERIMENTS.md §Perf P1).
    fn col_sqhinge_grad(&self, j: usize, y: &[f64], z: &[f64], b: f64) -> f64 {
        let mut g = 0.0;
        self.col_visit(j, &mut |i, v| {
            let xi = (1.0 - y[i] * (z[i] + b)).max(0.0);
            g -= v * y[i] * xi;
        });
        g
    }

    /// Squared norm of column `j`.
    fn col_norm_sq(&self, j: usize) -> f64 {
        let mut buf = vec![0.0; self.n_samples()];
        self.col_axpy(j, 1.0, &mut buf);
        crate::linalg::nrm2_sq(&buf)
    }

    /// Densifies column `j` into `buf` (len n, zeroed by the callee).
    fn densify_col(&self, j: usize, buf: &mut [f64]) {
        buf.iter_mut().for_each(|v| *v = 0.0);
        self.col_axpy(j, 1.0, buf);
    }

    /// Computes scores `out = X w` for dense `w` (len m), `out` len n.
    ///
    /// Skips exact-zero weights, so cost is O(Σ_{j: w_j≠0} nnz_j) — this
    /// is the warm-start-friendly form the path runner relies on.
    fn matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_features());
        assert_eq!(out.len(), self.n_samples());
        out.iter_mut().for_each(|v| *v = 0.0);
        for (j, &wj) in w.iter().enumerate() {
            if wj != 0.0 {
                self.col_axpy(j, wj, out);
            }
        }
    }

    /// Computes `out = Xᵀ v`, i.e. `out[j] = f_jᵀ v`, for all features.
    fn matvec_t(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.n_samples());
        assert_eq!(out.len(), self.n_features());
        for j in 0..self.n_features() {
            out[j] = self.col_dot(j, v);
        }
    }

    /// Total non-zeros (for reporting / cost models).
    fn nnz(&self) -> usize {
        (0..self.n_features()).map(|j| self.col_nnz(j)).sum()
    }

    /// Density in [0, 1].
    fn density(&self) -> f64 {
        let cells = self.n_samples() * self.n_features();
        if cells == 0 {
            0.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }
}

/// Owning dense-or-sparse feature storage with static dispatch.
#[derive(Debug, Clone, PartialEq)]
pub enum FeatureData {
    /// Column-major dense storage.
    Dense(dense::DenseMatrix),
    /// Compressed-sparse-column storage.
    Sparse(csc::CscMatrix),
}

macro_rules! dispatch {
    ($self:ident, $m:ident ( $($arg:expr),* )) => {
        match $self {
            FeatureData::Dense(x) => x.$m($($arg),*),
            FeatureData::Sparse(x) => x.$m($($arg),*),
        }
    };
}

impl FeatureMatrix for FeatureData {
    fn n_samples(&self) -> usize {
        dispatch!(self, n_samples())
    }
    fn n_features(&self) -> usize {
        dispatch!(self, n_features())
    }
    fn col_nnz(&self, j: usize) -> usize {
        dispatch!(self, col_nnz(j))
    }
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        dispatch!(self, col_dot(j, v))
    }
    fn col_dot_seq(&self, j: usize, v: &[f64]) -> f64 {
        dispatch!(self, col_dot_seq(j, v))
    }
    fn col_dot4(&self, j: usize, y: &[f64], theta: &[f64]) -> (f64, f64, f64, f64) {
        dispatch!(self, col_dot4(j, y, theta))
    }
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        dispatch!(self, col_axpy(j, alpha, out))
    }
    fn col_visit(&self, j: usize, f: &mut dyn FnMut(usize, f64)) {
        dispatch!(self, col_visit(j, f))
    }
    fn col_sqhinge_grad(&self, j: usize, y: &[f64], z: &[f64], b: f64) -> f64 {
        dispatch!(self, col_sqhinge_grad(j, y, z, b))
    }
    fn col_norm_sq(&self, j: usize) -> f64 {
        dispatch!(self, col_norm_sq(j))
    }
    fn nnz(&self) -> usize {
        dispatch!(self, nnz())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_dense() -> dense::DenseMatrix {
        // 3 samples x 2 features: f0 = [1,2,3], f1 = [0,-1,1]
        dense::DenseMatrix::from_cols(3, vec![vec![1.0, 2.0, 3.0], vec![0.0, -1.0, 1.0]])
    }

    #[test]
    fn trait_default_matvec() {
        let x = FeatureData::Dense(toy_dense());
        let mut out = vec![0.0; 3];
        x.matvec(&[2.0, 1.0], &mut out);
        assert_eq!(out, vec![2.0, 3.0, 7.0]);
    }

    #[test]
    fn trait_default_matvec_t() {
        let x = FeatureData::Dense(toy_dense());
        let mut out = vec![0.0; 2];
        x.matvec_t(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![6.0, 0.0]);
    }

    #[test]
    fn density_and_nnz() {
        // nnz counts STORED entries: dense storage stores all cells.
        let x = FeatureData::Dense(toy_dense());
        assert_eq!(x.nnz(), 6);
        assert!((x.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn densify_col_roundtrip() {
        let x = toy_dense();
        let mut buf = vec![9.0; 3];
        x.densify_col(1, &mut buf);
        assert_eq!(buf, vec![0.0, -1.0, 1.0]);
    }
}
