//! Diagnostics: screening provenance and solver-convergence recorders.
//!
//! The rest of the telemetry stack ([`crate::telemetry`]) answers
//! *aggregate* questions — rejection ratios, latency percentiles,
//! counter totals. This module answers the two per-entity questions
//! those aggregates cannot:
//!
//! * **"Which rule screened feature j at λ, and by what margin?"** —
//!   the [`ledger`] records one [`ledger::Verdict`] per feature per
//!   sweep (rule, bound vs. threshold, normalized margin, kept or
//!   rejected, near-miss flag) into a bounded, lock-sharded ring.
//!   Margin magnitudes aggregate into the `screening.margin.kept` /
//!   `screening.margin.rejected` histograms and near-misses into
//!   per-rule `screening.<rule>.near_miss` counters.
//! * **"Why did the solver stall on this reduced problem?"** — the
//!   [`convergence`] monitor watches every duality-gap check in CD and
//!   FISTA, detects stalls / divergence / non-finite gaps, increments
//!   `solver.anomalies`, emits warn instants into the trace ring, and
//!   archives a per-solve summary in a bounded global log.
//!
//! Surfaces: the `pallas explain` CLI subcommand (per-feature query,
//! top-N near-misses, JSONL/CSV export via [`crate::report::diag`]),
//! the `{"cmd":"diag"}` protocol command on the server, and per-step
//! `near_miss` / `anomalies` fields on
//! [`crate::path::stats::PathStep`].
//!
//! Recording is **observational only**: the ledger reads finished
//! [`crate::screening::rule::ScreenReport`]s, so screening results are
//! bit-identical with the ledger on or off (asserted in
//! `rust/tests/diag.rs`). The ledger is disabled by default; enable it
//! with `PALLAS_LEDGER=1`, the `--ledger` CLI flag, a
//! `{"cmd":"diag","enable":true}` request, or
//! [`ledger::Ledger::set_enabled`]. The convergence monitor is always
//! on (it only works at gap checks, which are already O(nnz)).

pub mod convergence;
pub mod ledger;

pub use convergence::{log_snapshot, ConvergenceSummary, Monitor};
pub use ledger::{Ledger, LedgerSummary, Verdict};
