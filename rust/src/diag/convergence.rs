//! The solver convergence recorder: per-gap-check traces for CD and
//! FISTA with stall / divergence / non-finite detection.
//!
//! A [`Monitor`] is created per solve and observed at every duality-gap
//! check (the solvers already pay O(nnz) there, so observation is
//! noise). Anomalies increment `solver.anomalies` (and the per-solver
//! `solver.<kind>.anomalies`) and emit a `solver.anomaly` warn event —
//! warn-level events are mirrored into the trace ring as instants, so
//! a stalled solve is visible in the exported Chrome trace. When the
//! solve finishes, [`Monitor::finish`] archives a
//! [`ConvergenceSummary`] (bounded gap trace included) into a global
//! bounded log queryable via [`log_snapshot`], the `pallas explain`
//! subcommand and `{"cmd":"diag","solver":true}`.

use crate::coordinator::protocol::Json;
use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};

/// Gap checks without meaningful improvement before a stall fires.
pub const DEFAULT_STALL_WINDOW: usize = 8;

/// A gap this many times the best-seen gap counts as divergence.
pub const DEFAULT_DIVERGENCE_FACTOR: f64 = 10.0;

/// Relative improvement below which a gap check counts as "no
/// progress" for stall detection.
const REL_IMPROVEMENT: f64 = 1e-3;

/// Max `(iteration, rel_gap)` points kept per solve.
const MAX_TRACE: usize = 512;

/// Max archived [`ConvergenceSummary`] entries in the global log.
const LOG_CAPACITY: usize = 256;

/// Archived outcome of one monitored solve.
#[derive(Debug, Clone)]
pub struct ConvergenceSummary {
    /// Solver name (`"cd"` / `"fista"`).
    pub solver: &'static str,
    /// The solve's λ.
    pub lambda: f64,
    /// Iterations/epochs run.
    pub iterations: usize,
    /// Whether the solver reported convergence.
    pub converged: bool,
    /// Final relative duality gap.
    pub rel_gap: f64,
    /// Gap checks observed.
    pub checks: usize,
    /// Total anomalies (stalls + divergences + non-finite gaps).
    pub anomalies: usize,
    /// Stall anomalies.
    pub stalls: usize,
    /// Divergence anomalies.
    pub divergences: usize,
    /// The `(iteration, rel_gap)` trace (capped at [`MAX_TRACE`]).
    pub trace: Vec<(usize, f64)>,
}

impl ConvergenceSummary {
    /// Protocol-JSON view (non-finite numbers become `null`).
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let trace = Json::Arr(
            self.trace
                .iter()
                .map(|&(it, g)| Json::Arr(vec![Json::Num(it as f64), num(g)]))
                .collect(),
        );
        Json::obj(vec![
            ("solver", Json::Str(self.solver.into())),
            ("lambda", num(self.lambda)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("converged", Json::Bool(self.converged)),
            ("rel_gap", num(self.rel_gap)),
            ("checks", Json::Num(self.checks as f64)),
            ("anomalies", Json::Num(self.anomalies as f64)),
            ("stalls", Json::Num(self.stalls as f64)),
            ("divergences", Json::Num(self.divergences as f64)),
            ("trace", trace),
        ])
    }
}

/// Per-solve convergence monitor. Cheap enough to be always on: it
/// only does work at gap checks, which already cost a full data pass.
#[derive(Debug)]
pub struct Monitor {
    solver: &'static str,
    lambda: f64,
    stall_window: usize,
    divergence_factor: f64,
    best_gap: f64,
    since_improvement: usize,
    checks: usize,
    anomalies: usize,
    stalls: usize,
    divergences: usize,
    trace: Vec<(usize, f64)>,
}

impl Monitor {
    /// Creates a monitor with the default stall/divergence thresholds.
    pub fn new(solver: &'static str, lambda: f64) -> Self {
        Monitor {
            solver,
            lambda,
            stall_window: DEFAULT_STALL_WINDOW,
            divergence_factor: DEFAULT_DIVERGENCE_FACTOR,
            best_gap: f64::INFINITY,
            since_improvement: 0,
            checks: 0,
            anomalies: 0,
            stalls: 0,
            divergences: 0,
            trace: Vec::new(),
        }
    }

    /// Overrides the stall window (gap checks without improvement).
    pub fn with_stall_window(mut self, window: usize) -> Self {
        self.stall_window = window.max(1);
        self
    }

    /// Anomalies detected so far.
    pub fn anomalies(&self) -> usize {
        self.anomalies
    }

    /// Observes one duality-gap check.
    pub fn observe(&mut self, iteration: usize, rel_gap: f64) {
        self.checks += 1;
        if self.trace.len() < MAX_TRACE {
            self.trace.push((iteration, rel_gap));
        }
        if !rel_gap.is_finite() {
            self.anomaly("non-finite gap", iteration, rel_gap);
            return;
        }
        if rel_gap > self.divergence_factor * self.best_gap {
            self.divergences += 1;
            self.anomaly("divergence", iteration, rel_gap);
            // Re-baseline so a persistent plateau at the higher level
            // doesn't re-fire on every subsequent check.
            self.best_gap = rel_gap;
            self.since_improvement = 0;
            return;
        }
        if rel_gap < self.best_gap * (1.0 - REL_IMPROVEMENT) {
            self.best_gap = rel_gap;
            self.since_improvement = 0;
            return;
        }
        self.best_gap = self.best_gap.min(rel_gap);
        self.since_improvement += 1;
        if self.since_improvement >= self.stall_window {
            self.stalls += 1;
            self.anomaly("stall", iteration, rel_gap);
            self.since_improvement = 0;
        }
    }

    fn anomaly(&mut self, kind: &str, iteration: usize, rel_gap: f64) {
        self.anomalies += 1;
        let tele = crate::telemetry::global();
        tele.counter("solver.anomalies").inc();
        tele.counter(&format!("solver.{}.anomalies", self.solver)).inc();
        crate::tele_warn!(
            "solver.anomaly",
            "{} {} at iter {} (lambda {:.4e}, rel_gap {:.3e}, best {:.3e})",
            self.solver,
            kind,
            iteration,
            self.lambda,
            rel_gap,
            self.best_gap
        );
    }

    /// Seals the monitor: archives a [`ConvergenceSummary`] into the
    /// global log and returns the anomaly count (what lands in
    /// `SolveReport::anomalies`).
    pub fn finish(self, iterations: usize, converged: bool, rel_gap: f64) -> usize {
        let anomalies = self.anomalies;
        let summary = ConvergenceSummary {
            solver: self.solver,
            lambda: self.lambda,
            iterations,
            converged,
            rel_gap,
            checks: self.checks,
            anomalies,
            stalls: self.stalls,
            divergences: self.divergences,
            trace: self.trace,
        };
        let mut log = log().lock().unwrap();
        if log.len() >= LOG_CAPACITY {
            log.pop_front();
        }
        log.push_back(summary);
        anomalies
    }
}

fn log() -> &'static Mutex<VecDeque<ConvergenceSummary>> {
    static LOG: OnceLock<Mutex<VecDeque<ConvergenceSummary>>> = OnceLock::new();
    LOG.get_or_init(|| Mutex::new(VecDeque::new()))
}

/// The archived summaries, oldest first (bounded at [`LOG_CAPACITY`]).
pub fn log_snapshot() -> Vec<ConvergenceSummary> {
    log().lock().unwrap().iter().cloned().collect()
}

/// Clears the archive (test isolation helper).
pub fn clear_log() {
    log().lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_progress_is_clean() {
        let mut m = Monitor::new("cd", 0.5);
        let mut gap = 1.0;
        for it in 1..=20 {
            m.observe(it, gap);
            gap *= 0.5;
        }
        assert_eq!(m.anomalies(), 0);
        assert_eq!(m.finish(20, true, gap), 0);
    }

    #[test]
    fn plateau_fires_stall_every_window() {
        let mut m = Monitor::new("cd", 0.5).with_stall_window(4);
        m.observe(1, 1e-3);
        for it in 2..=9 {
            m.observe(it, 1e-3); // 8 flat checks = 2 windows
        }
        assert_eq!(m.anomalies(), 2);
    }

    #[test]
    fn divergence_fires_once_then_rebaselines() {
        let mut m = Monitor::new("fista", 0.5);
        m.observe(1, 1e-4);
        m.observe(2, 5e-3); // 50x jump
        assert_eq!(m.anomalies(), 1);
        m.observe(3, 5e-3); // plateau at the new level: no re-fire
        assert_eq!(m.anomalies(), 1);
    }

    #[test]
    fn non_finite_gap_is_an_anomaly() {
        let mut m = Monitor::new("cd", 0.5);
        m.observe(1, f64::NAN);
        assert_eq!(m.anomalies(), 1);
    }

    #[test]
    fn finish_archives_summary_with_trace() {
        // Lib tests share the global log across threads, so find our
        // entry by its unique lambda instead of asserting on `last()`.
        let mut m = Monitor::new("fista", 0.252_518);
        m.observe(10, 1e-2);
        m.observe(20, 1e-4);
        let n = m.finish(20, true, 1e-4);
        assert_eq!(n, 0);
        let log = log_snapshot();
        let mine = log.iter().find(|s| s.lambda == 0.252_518).unwrap();
        assert_eq!(mine.solver, "fista");
        assert_eq!(mine.trace, vec![(10, 1e-2), (20, 1e-4)]);
        assert!(mine.converged);
        let enc = mine.to_json().encode();
        assert!(enc.contains("\"solver\":\"fista\""), "{enc}");
    }
}
