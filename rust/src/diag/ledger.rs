//! The screening provenance ledger: one [`Verdict`] per feature per
//! sweep, answering "which rule screened feature j at λ, and by what
//! margin?".
//!
//! The ledger is **observational**: it reads finished
//! [`ScreenReport`]s after the keep/bounds vectors are sealed, so
//! screening results are bit-identical whether it is enabled or not
//! (asserted in `rust/tests/diag.rs`). It is disabled by default
//! because a path run over a wide feature matrix produces one verdict
//! per feature per step; when enabled, records land in a bounded
//! lock-sharded ring (shard = `feature % SHARDS`, so one feature's
//! history lives in one shard) and the oldest records are evicted —
//! and counted — when a shard fills.
//!
//! Enabled or not is independent of the *aggregate* screening
//! telemetry in [`crate::screening::rule`], which is always on. When
//! the ledger is enabled it additionally feeds:
//!
//! * `screening.margin.kept` / `screening.margin.rejected` histograms
//!   ([`BucketSpec::MARGINS`] buckets over `|margin|`) — bound
//!   tightness at a glance,
//! * `screening.near_miss` and `screening.<rule>.near_miss` counters —
//!   features whose bound landed within ε of the keep threshold,
//! * `diag.ledger.recorded` / `diag.ledger.dropped` counters.
//!
//! [`ScreenReport`]: crate::screening::rule::ScreenReport

use crate::coordinator::protocol::Json;
use crate::screening::rule::{ScreenReport, KEEP_THRESHOLD};
use crate::screening::variants::AuditReport;
use crate::telemetry::BucketSpec;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Number of lock shards (records shard by `feature % SHARDS`).
pub const SHARDS: usize = 16;

/// Default total capacity (verdicts) across all shards.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Default near-miss epsilon: a feature is a near-miss when its bound
/// lands within this distance of [`KEEP_THRESHOLD`] (either side).
pub const DEFAULT_NEAR_MISS_EPS: f64 = 1e-2;

/// One per-feature screening decision with full provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct Verdict {
    /// Feature index.
    pub feature: usize,
    /// Rule that produced the decision (`RuleKind::name`).
    pub rule: &'static str,
    /// Source λ (where the dual point was solved).
    pub lambda1: f64,
    /// Target λ (where the feature was screened).
    pub lambda2: f64,
    /// The rule's bound/score for this feature.
    pub bound: f64,
    /// The keep threshold the bound was compared against.
    pub threshold: f64,
    /// Normalized margin `bound − threshold` (&gt; 0 ⇔ kept; `+∞` for
    /// unconditional keeps, e.g. the `none` rule).
    pub margin: f64,
    /// Whether the feature survived screening.
    pub kept: bool,
    /// Whether `|margin|` fell below the configured epsilon.
    pub near_miss: bool,
    /// Which sweep path recorded it: `"seq"`, `"batch"`, `"par"` or
    /// `"audit"`.
    pub source: &'static str,
    /// Monotone sweep sequence number (one per recorded report).
    pub sweep: u64,
}

impl Verdict {
    /// CSV header matching [`Verdict::csv_row`].
    pub const CSV_HEADER: [&'static str; 11] = [
        "sweep", "feature", "rule", "source", "lambda1", "lambda2", "bound", "threshold",
        "margin", "kept", "near_miss",
    ];

    /// One CSV row (same column order as [`Verdict::CSV_HEADER`]).
    pub fn csv_row(&self) -> Vec<String> {
        vec![
            self.sweep.to_string(),
            self.feature.to_string(),
            self.rule.to_string(),
            self.source.to_string(),
            format!("{:.6e}", self.lambda1),
            format!("{:.6e}", self.lambda2),
            format!("{:.6e}", self.bound),
            format!("{:.6e}", self.threshold),
            format!("{:.6e}", self.margin),
            self.kept.to_string(),
            self.near_miss.to_string(),
        ]
    }

    /// Protocol-JSON view (non-finite numbers become `null`).
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        Json::obj(vec![
            ("sweep", Json::Num(self.sweep as f64)),
            ("feature", Json::Num(self.feature as f64)),
            ("rule", Json::Str(self.rule.into())),
            ("source", Json::Str(self.source.into())),
            ("lambda1", num(self.lambda1)),
            ("lambda2", num(self.lambda2)),
            ("bound", num(self.bound)),
            ("threshold", num(self.threshold)),
            ("margin", num(self.margin)),
            ("kept", Json::Bool(self.kept)),
            ("near_miss", Json::Bool(self.near_miss)),
        ])
    }
}

/// Counts how many bounds land within `eps` of [`KEEP_THRESHOLD`] —
/// the per-step near-miss summary the path runner reports even when
/// the ledger itself is disabled.
pub fn near_miss_count(bounds: &[f64], eps: f64) -> usize {
    bounds
        .iter()
        .filter(|b| {
            let margin = **b - KEEP_THRESHOLD;
            margin.is_finite() && margin.abs() < eps
        })
        .count()
}

/// Aggregate view of the ledger (the `{"cmd":"diag"}` payload).
#[derive(Debug, Clone)]
pub struct LedgerSummary {
    /// Whether recording is currently enabled.
    pub enabled: bool,
    /// The configured near-miss epsilon.
    pub near_miss_eps: f64,
    /// Verdicts recorded since process start (monotone).
    pub recorded: u64,
    /// Verdicts evicted because a shard filled (monotone).
    pub dropped: u64,
    /// Verdicts currently buffered across all shards.
    pub buffered: usize,
    /// Buffered near-miss verdicts.
    pub near_misses: usize,
    /// Per-rule `(kept, rejected, near_miss)` breakdown of the buffer.
    pub by_rule: Vec<(&'static str, usize, usize, usize)>,
}

impl LedgerSummary {
    /// Protocol-JSON view.
    pub fn to_json(&self) -> Json {
        let by_rule = Json::Obj(
            self.by_rule
                .iter()
                .map(|&(rule, kept, rejected, near)| {
                    (
                        rule.to_string(),
                        Json::obj(vec![
                            ("kept", Json::Num(kept as f64)),
                            ("rejected", Json::Num(rejected as f64)),
                            ("near_miss", Json::Num(near as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled)),
            ("near_miss_eps", Json::Num(self.near_miss_eps)),
            ("recorded", Json::Num(self.recorded as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("buffered", Json::Num(self.buffered as f64)),
            ("near_misses", Json::Num(self.near_misses as f64)),
            ("by_rule", by_rule),
        ])
    }
}

/// The bounded, lock-sharded provenance ledger.
#[derive(Debug)]
pub struct Ledger {
    capacity_per_shard: usize,
    enabled: AtomicBool,
    eps_bits: AtomicU64,
    sweep_seq: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
    shards: Vec<Mutex<VecDeque<Verdict>>>,
}

impl Ledger {
    /// Creates a ledger holding at most `capacity` verdicts total,
    /// recording disabled.
    pub fn new(capacity: usize) -> Self {
        Ledger {
            capacity_per_shard: capacity.div_ceil(SHARDS).max(1),
            enabled: AtomicBool::new(false),
            eps_bits: AtomicU64::new(DEFAULT_NEAR_MISS_EPS.to_bits()),
            sweep_seq: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            shards: (0..SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
        }
    }

    /// Turns recording on/off.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether recording is on.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// The configured near-miss epsilon.
    pub fn near_miss_eps(&self) -> f64 {
        f64::from_bits(self.eps_bits.load(Ordering::Relaxed))
    }

    /// Sets the near-miss epsilon (non-finite/negative values ignored).
    pub fn set_near_miss_eps(&self, eps: f64) {
        if eps.is_finite() && eps >= 0.0 {
            self.eps_bits.store(eps.to_bits(), Ordering::Relaxed);
        }
    }

    /// Records every per-feature verdict of a finished sweep. No-op
    /// (one relaxed load) when disabled — the margin histograms and
    /// near-miss counters are gated with it, so enabling the ledger is
    /// the single switch for all per-feature observability.
    pub fn record_report(&self, report: &ScreenReport, source: &'static str) {
        if !self.enabled() {
            return;
        }
        let eps = self.near_miss_eps();
        let sweep = self.sweep_seq.fetch_add(1, Ordering::Relaxed);
        let rule = report.rule.name();
        let tele = crate::telemetry::global();
        let margin_kept =
            tele.histogram_with("screening.margin.kept", BucketSpec::MARGINS);
        let margin_rejected =
            tele.histogram_with("screening.margin.rejected", BucketSpec::MARGINS);
        let mut near = 0u64;
        for (j, (&bound, &kept)) in report.bounds.iter().zip(&report.keep).enumerate() {
            let margin = bound - KEEP_THRESHOLD;
            let near_miss = margin.is_finite() && margin.abs() < eps;
            near += near_miss as u64;
            if margin.is_finite() {
                let h = if kept { &margin_kept } else { &margin_rejected };
                h.record(margin.abs());
            }
            self.push(Verdict {
                feature: j,
                rule,
                lambda1: report.lambda1,
                lambda2: report.lambda2,
                bound,
                threshold: KEEP_THRESHOLD,
                margin,
                kept,
                near_miss,
                source,
                sweep,
            });
        }
        if near > 0 {
            tele.counter("screening.near_miss").add(near);
            tele.counter(&format!("screening.{rule}.near_miss")).add(near);
        }
        tele.counter("diag.ledger.recorded").add(report.keep.len() as u64);
    }

    /// Records an audit's violations (screened-out features whose KKT
    /// correlation exceeds 1): `bound` is the measured `|f̂ᵀθ|`, the
    /// threshold is the KKT limit 1, and the margin is the excess.
    pub fn record_audit(&self, report: &ScreenReport, audit: &AuditReport) {
        if !self.enabled() || audit.violations.is_empty() {
            return;
        }
        let sweep = self.sweep_seq.fetch_add(1, Ordering::Relaxed);
        for v in &audit.violations {
            self.push(Verdict {
                feature: v.feature,
                rule: report.rule.name(),
                lambda1: report.lambda1,
                lambda2: report.lambda2,
                bound: v.correlation,
                threshold: 1.0,
                margin: v.correlation - 1.0,
                kept: false,
                near_miss: false,
                source: "audit",
                sweep,
            });
        }
        crate::telemetry::global()
            .counter("diag.ledger.recorded")
            .add(audit.violations.len() as u64);
    }

    fn push(&self, v: Verdict) {
        let mut shard = self.shards[v.feature % SHARDS].lock().unwrap();
        if shard.len() >= self.capacity_per_shard {
            shard.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::global().counter("diag.ledger.dropped").inc();
        }
        shard.push_back(v);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Every buffered verdict for feature `j`, oldest first.
    pub fn feature_history(&self, j: usize) -> Vec<Verdict> {
        let shard = self.shards[j % SHARDS].lock().unwrap();
        shard.iter().filter(|v| v.feature == j).cloned().collect()
    }

    /// Every buffered near-miss verdict, tightest margin first.
    pub fn near_misses(&self) -> Vec<Verdict> {
        let mut out: Vec<Verdict> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.lock().unwrap().iter().filter(|v| v.near_miss).cloned().collect::<Vec<_>>()
            })
            .collect();
        out.sort_by(|a, b| {
            a.margin
                .abs()
                .partial_cmp(&b.margin.abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.feature.cmp(&b.feature))
                .then(a.sweep.cmp(&b.sweep))
        });
        out
    }

    /// The `n` buffered near-misses with the tightest margins.
    pub fn top_near_misses(&self, n: usize) -> Vec<Verdict> {
        let mut out = self.near_misses();
        out.truncate(n);
        out
    }

    /// Every buffered verdict, ordered by `(sweep, feature)`.
    pub fn snapshot(&self) -> Vec<Verdict> {
        let mut out: Vec<Verdict> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().iter().cloned().collect::<Vec<_>>())
            .collect();
        out.sort_by(|a, b| a.sweep.cmp(&b.sweep).then(a.feature.cmp(&b.feature)));
        out
    }

    /// Number of buffered verdicts.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Verdicts evicted so far (monotone).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Clears the buffer (the monotone recorded/dropped totals and the
    /// sweep sequence are kept).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// Aggregate view of the current buffer.
    pub fn summary(&self) -> LedgerSummary {
        let mut by_rule: Vec<(&'static str, usize, usize, usize)> = Vec::new();
        let mut near_misses = 0usize;
        let mut buffered = 0usize;
        for s in &self.shards {
            for v in s.lock().unwrap().iter() {
                buffered += 1;
                near_misses += v.near_miss as usize;
                let entry = match by_rule.iter_mut().find(|(r, ..)| *r == v.rule) {
                    Some(e) => e,
                    None => {
                        by_rule.push((v.rule, 0, 0, 0));
                        by_rule.last_mut().unwrap()
                    }
                };
                if v.kept {
                    entry.1 += 1;
                } else {
                    entry.2 += 1;
                }
                entry.3 += v.near_miss as usize;
            }
        }
        by_rule.sort_by_key(|e| e.0);
        LedgerSummary {
            enabled: self.enabled(),
            near_miss_eps: self.near_miss_eps(),
            recorded: self.recorded.load(Ordering::Relaxed),
            dropped: self.dropped(),
            buffered,
            near_misses,
            by_rule,
        }
    }
}

/// The process-wide ledger. Capacity comes from
/// `PALLAS_LEDGER_CAPACITY` (default [`DEFAULT_CAPACITY`]); recording
/// starts enabled iff `PALLAS_LEDGER` is `1`/`true`/`yes`/`on`; the
/// epsilon can be preset with `PALLAS_NEAR_MISS_EPS`.
pub fn global() -> &'static Ledger {
    static GLOBAL: OnceLock<Ledger> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let capacity = std::env::var("PALLAS_LEDGER_CAPACITY")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(DEFAULT_CAPACITY);
        let ledger = Ledger::new(capacity);
        if let Ok(v) = std::env::var("PALLAS_LEDGER") {
            let v = v.to_ascii_lowercase();
            ledger.set_enabled(matches!(v.as_str(), "1" | "true" | "yes" | "on"));
        }
        if let Ok(v) = std::env::var("PALLAS_NEAR_MISS_EPS") {
            if let Ok(eps) = v.parse::<f64>() {
                ledger.set_near_miss_eps(eps);
            }
        }
        ledger
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::rule::RuleKind;

    fn report(rule: RuleKind, bounds: Vec<f64>) -> ScreenReport {
        let keep = bounds.iter().map(|&b| b >= KEEP_THRESHOLD).collect();
        ScreenReport { rule, lambda1: 1.0, lambda2: 0.5, keep, bounds, seconds: 0.0 }
    }

    #[test]
    fn disabled_ledger_records_nothing() {
        let l = Ledger::new(64);
        l.record_report(&report(RuleKind::Paper, vec![2.0, 0.1]), "seq");
        assert!(l.is_empty());
        assert!(!l.summary().enabled);
    }

    #[test]
    fn verdicts_match_report_and_flag_near_misses() {
        let l = Ledger::new(64);
        l.set_enabled(true);
        l.set_near_miss_eps(1e-2);
        let rep =
            report(RuleKind::Paper, vec![2.0, 0.1, 1.0 + 5e-3, KEEP_THRESHOLD - 5e-3]);
        l.record_report(&rep, "seq");
        let all = l.snapshot();
        assert_eq!(all.len(), 4);
        for (j, v) in all.iter().enumerate() {
            assert_eq!(v.feature, j);
            assert_eq!(v.kept, rep.keep[j], "feature {j}");
            assert_eq!(v.bound, rep.bounds[j]);
            assert_eq!(v.margin, rep.bounds[j] - KEEP_THRESHOLD);
            assert_eq!(v.rule, "paper");
            assert_eq!(v.source, "seq");
        }
        assert!(!all[0].near_miss && !all[1].near_miss);
        assert!(all[2].near_miss && all[3].near_miss);
        // top-N sorts by |margin|: feature 3 (5e-3 below threshold) and
        // feature 2 (~5e-3 above, slightly larger due to KEEP_MARGIN).
        let top = l.top_near_misses(1);
        assert_eq!(top.len(), 1);
        assert!(top[0].margin.abs() <= l.near_misses()[1].margin.abs());
        assert_eq!(near_miss_count(&rep.bounds, 1e-2), 2);
    }

    #[test]
    fn feature_history_isolates_one_feature() {
        let l = Ledger::new(1024);
        l.set_enabled(true);
        for step in 0..5 {
            let mut rep = report(RuleKind::Sphere, vec![2.0; 40]);
            rep.lambda2 = 1.0 - 0.1 * step as f64;
            l.record_report(&rep, "par");
        }
        let h = l.feature_history(17);
        assert_eq!(h.len(), 5);
        for (i, v) in h.iter().enumerate() {
            assert_eq!(v.feature, 17);
            assert_eq!(v.sweep, i as u64);
        }
        // sweeps arrive oldest-first
        assert!(h[0].lambda2 > h[4].lambda2);
    }

    #[test]
    fn bounded_shards_evict_and_count_drops() {
        let l = Ledger::new(SHARDS); // one verdict per shard
        l.set_enabled(true);
        let rep = report(RuleKind::Paper, vec![2.0; 3 * SHARDS]);
        l.record_report(&rep, "seq");
        assert_eq!(l.len(), SHARDS);
        assert_eq!(l.dropped(), 2 * SHARDS as u64);
        let s = l.summary();
        assert_eq!(s.recorded, 3 * SHARDS as u64);
        assert_eq!(s.dropped, 2 * SHARDS as u64);
        assert_eq!(s.buffered, SHARDS);
        // survivors are the newest verdicts (largest feature indices)
        assert!(l.snapshot().iter().all(|v| v.feature >= 2 * SHARDS));
    }

    #[test]
    fn summary_breaks_down_by_rule_and_encodes() {
        let l = Ledger::new(256);
        l.set_enabled(true);
        l.record_report(&report(RuleKind::Paper, vec![2.0, 0.1]), "seq");
        l.record_report(&report(RuleKind::Sphere, vec![0.2, 1.0 + 1e-3]), "batch");
        let s = l.summary();
        assert_eq!(s.buffered, 4);
        assert_eq!(s.near_misses, 1);
        assert_eq!(s.by_rule, vec![("paper", 1, 1, 0), ("sphere", 1, 1, 1)]);
        let enc = s.to_json().encode();
        assert!(enc.contains("\"by_rule\""), "{enc}");
        assert!(enc.contains("\"sphere\""), "{enc}");
        let enc_v = l.snapshot()[0].to_json().encode();
        assert!(enc_v.contains("\"rule\":\"paper\""), "{enc_v}");
    }

    #[test]
    fn audit_hook_records_violations() {
        use crate::screening::variants::Violation;
        let l = Ledger::new(64);
        l.set_enabled(true);
        let rep = report(RuleKind::Strong, vec![0.1, 0.2]);
        let audit = AuditReport {
            rule: RuleKind::Strong,
            lambda2: 0.5,
            checked: 2,
            tol: 1e-8,
            violations: vec![Violation { feature: 1, correlation: 1.25, weight: 0.0 }],
        };
        l.record_audit(&rep, &audit);
        let h = l.feature_history(1);
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].source, "audit");
        assert!((h[0].margin - 0.25).abs() < 1e-12);
        assert!(!h[0].kept);
    }

    #[test]
    fn csv_row_matches_header_width() {
        let l = Ledger::new(16);
        l.set_enabled(true);
        l.record_report(&report(RuleKind::Paper, vec![2.0]), "seq");
        let v = &l.snapshot()[0];
        assert_eq!(v.csv_row().len(), Verdict::CSV_HEADER.len());
    }
}
