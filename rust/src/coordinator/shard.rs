//! Sharded screening coordinator: per-shard cache reuse across batches.
//!
//! The screening service holds the whole dataset resident and re-screens
//! it for every batch. Sharding splits the *feature* axis into `K`
//! contiguous, nnz-balanced shards ([`ShardPlan`], balanced off the
//! cached per-column nnz), and gives each shard its own long-lived
//! [`ReducedProblem`] — gathered columns plus a remapped
//! [`crate::data::cache::FeatureCache`] — that persists across server
//! batches. A batch sweep fans out across shards, each paying only its
//! slice of the O(nnz) θ-dot pass, and the merged kept set is
//! **bit-identical** to the unsharded sweep (asserted in
//! `rust/tests/shard.rs`): the per-feature arithmetic is unchanged —
//! remapped cache entries are copies of the full cache's accumulators,
//! gathered column bytes are copies of the full matrix's columns, and
//! the merge concatenates shard bounds back into original feature order.
//!
//! This is the simultaneous feature/sample-reduction scaling direction
//! of Zhang et al. (arXiv:1607.06996) and the data-reduction serving
//! shape of Wang et al. (arXiv:1310.7048) applied to the feature axis.
//!
//! ## Telemetry
//!
//! Each shard registers `coordinator.shard.<k>.kept` /
//! `coordinator.shard.<k>.screened` counters and a
//! `coordinator.shard.<k>.seconds` sweep-latency histogram, plus
//! build-time gauges `coordinator.shard.count`,
//! `coordinator.shard.<k>.nnz` and `coordinator.shard.imbalance`
//! (max shard nnz over mean). Every shard sweep records a
//! `coordinator.shard` span (labeled with the shard id) in the trace
//! ring. All of it surfaces through `{"cmd":"stats"}` and the
//! Prometheus rendering.

use crate::coordinator::blocks;
use crate::coordinator::pool::parallel_map;
use crate::data::FeatureMatrix;
use crate::error::{Error, Result};
use crate::screening::precompute::{FeatureStats, SharedContext};
use crate::screening::rule::{
    record_screen_telemetry, Rule, RuleKind, ScreenReport, ScreeningRule, KEEP_THRESHOLD,
};
use crate::solver::reduced::ReducedProblem;
use crate::svm::problem::Problem;
use crate::telemetry::{self, Counter, Histogram, Span};
use std::ops::Range;
use std::sync::Arc;

/// A contiguous, nnz-balanced partition of the feature axis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Contiguous feature ranges, ascending, covering `0..m` exactly.
    pub ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Plans at most `k` shards over `col_nnz.len()` features, balanced
    /// by the cached per-column nnz. `k` is clamped to `[1, m]`; heavily
    /// skewed data may yield fewer shards than requested (the balancer
    /// never emits empty ranges).
    pub fn build(col_nnz: &[usize], k: usize) -> Self {
        ShardPlan { ranges: blocks::balanced_nnz(col_nnz, k) }
    }

    /// Number of planned shards.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// Whether the plan is empty (zero features).
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

/// Cached per-shard telemetry handles (registry lookups happen once, at
/// build; the sweep hot path touches only relaxed atomics).
struct ShardTele {
    kept: Arc<Counter>,
    screened: Arc<Counter>,
    seconds: Arc<Histogram>,
}

impl ShardTele {
    fn new(id: usize) -> Self {
        let t = telemetry::global();
        ShardTele {
            kept: t.counter(&format!("coordinator.shard.{id}.kept")),
            screened: t.counter(&format!("coordinator.shard.{id}.screened")),
            seconds: t.histogram(&format!("coordinator.shard.{id}.seconds")),
        }
    }
}

/// One shard: a slice of the feature space with its own long-lived
/// gathered submatrix and remapped cache.
pub struct Shard {
    /// Shard index (names the shard's metrics).
    pub id: usize,
    /// The shard's feature range in original coordinates.
    pub range: Range<usize>,
    /// Long-lived reduced problem: gathered columns + remapped cache,
    /// reused for every batch instead of re-gathering per sweep.
    red: ReducedProblem,
    tele: ShardTele,
}

impl Shard {
    /// Stored entries in this shard's columns.
    pub fn nnz(&self) -> usize {
        self.red.cache.as_ref().map(|c| c.nnz).unwrap_or(0)
    }
}

/// The sharded batch screener: owns `K` shards and screens batches of
/// λ₂ targets across them, merging kept sets bit-identically to the
/// unsharded [`crate::screening::rule::screen_multi_with`] sweep.
pub struct ShardedScreener {
    shards: Vec<Shard>,
    /// Total feature count (the merged report length).
    m: usize,
    /// Worker threads for the shard fan-out.
    workers: usize,
}

impl ShardedScreener {
    /// Builds `k` shards (clamped to `[1, m]`) over the problem's
    /// features, balanced by the problem cache's per-column nnz. Each
    /// shard gathers its columns once, here, and keeps them for the
    /// screener's lifetime.
    pub fn build(problem: &Problem, k: usize, workers: usize) -> Result<Self> {
        let m = problem.m();
        let cache = problem.cache();
        let plan = ShardPlan::build(&cache.col_nnz, k);
        let mut shards = Vec::with_capacity(plan.len());
        for (id, range) in plan.ranges.iter().enumerate() {
            let red = ReducedProblem::build_with(
                &problem.x,
                range.clone().collect(),
                Some(cache),
                workers,
            )?;
            debug_assert!(red.cache.is_some(), "shard gather must remap the cache");
            shards.push(Shard { id, range: range.clone(), red, tele: ShardTele::new(id) });
        }
        // Build-time shape gauges: shard count, per-shard nnz, and the
        // max-over-mean imbalance ratio (1.0 = perfectly balanced).
        let tele = telemetry::global();
        tele.gauge("coordinator.shard.count").set(shards.len() as f64);
        let nnzs: Vec<usize> = shards.iter().map(|s| s.nnz()).collect();
        for s in &shards {
            tele.gauge(&format!("coordinator.shard.{}.nnz", s.id)).set(s.nnz() as f64);
        }
        if !nnzs.is_empty() {
            let max = *nnzs.iter().max().unwrap() as f64;
            let mean = nnzs.iter().sum::<usize>() as f64 / nnzs.len() as f64;
            tele.gauge("coordinator.shard.imbalance")
                .set(if mean > 0.0 { max / mean } else { 1.0 });
        }
        Ok(ShardedScreener { shards, m, workers: workers.max(1) })
    }

    /// Number of live shards (≤ the requested `k`).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total feature count across shards.
    pub fn n_features(&self) -> usize {
        self.m
    }

    /// Screens every feature for each target λ₂ against the dual point
    /// `(lambda1, theta1)`, fanning the sweep out across shards. Same
    /// contract as [`crate::screening::rule::screen_multi_with`]: one
    /// report per target, `seconds` amortized over the batch, and the
    /// kept sets bit-identical to the unsharded sweep.
    pub fn screen_multi(
        &self,
        rule: RuleKind,
        y: &[f64],
        theta1: &[f64],
        lambda1: f64,
        lambda2s: &[f64],
    ) -> Result<Vec<ScreenReport>> {
        let t0 = std::time::Instant::now();
        let k = lambda2s.len();
        if k == 0 {
            return Ok(Vec::new());
        }
        if rule == RuleKind::None {
            // Keep-all short circuit, mirroring the unsharded path (which
            // skips context construction — and its λ validation — too).
            return Ok(lambda2s
                .iter()
                .map(|&l2| {
                    let rep = ScreenReport::from_bounds(
                        rule,
                        lambda1,
                        l2,
                        vec![f64::INFINITY; self.m],
                        t0.elapsed().as_secs_f64(),
                    );
                    record_screen_telemetry(&rep, 1, "shard");
                    rep
                })
                .collect());
        }
        let ctxs: Vec<SharedContext> = lambda2s
            .iter()
            .map(|&l2| SharedContext::build(y, theta1, lambda1, l2))
            .collect::<Result<_>>()?;
        let r = Rule(rule);
        // Fan out: each worker sweeps whole shards; per-shard scores are
        // per-target vectors in shard-local feature order.
        let shard_scores: Vec<Vec<Vec<f64>>> =
            parallel_map(&self.shards, self.workers, |shard| {
                let span = Span::enter_labeled(
                    "coordinator.shard",
                    Some(format!("shard {} ({} features)", shard.id, shard.range.len())),
                );
                let st = std::time::Instant::now();
                let cache = shard.red.cache.as_ref().expect("shard cache");
                let m_local = shard.red.x.n_features();
                let mut scores = vec![Vec::with_capacity(m_local); k];
                for j in 0..m_local {
                    // One θ-dot per feature: the λ-independent stats come
                    // from the shard's remapped cache.
                    let s = FeatureStats::from_cache(
                        &shard.red.x,
                        cache,
                        j,
                        &ctxs[0].ytheta1,
                    );
                    for (t, ctx) in ctxs.iter().enumerate() {
                        scores[t].push(r.score(ctx, &s));
                    }
                }
                shard.tele.seconds.record(st.elapsed().as_secs_f64());
                let kept: usize = scores
                    .iter()
                    .flat_map(|v| v.iter())
                    .filter(|&&b| b >= KEEP_THRESHOLD)
                    .count();
                shard.tele.kept.add(kept as u64);
                shard.tele.screened.add((k * m_local - kept) as u64);
                drop(span);
                scores
            });
        // Merge: shards are contiguous ascending ranges, so concatenating
        // shard bounds in shard order restores original feature order.
        let seconds = t0.elapsed().as_secs_f64() / k as f64;
        let reports: Vec<ScreenReport> = lambda2s
            .iter()
            .enumerate()
            .map(|(t, &l2)| {
                let mut bounds = Vec::with_capacity(self.m);
                for ss in &shard_scores {
                    bounds.extend_from_slice(&ss[t]);
                }
                ScreenReport::from_bounds(rule, lambda1, l2, bounds, seconds)
            })
            .collect();
        for (i, rep) in reports.iter().enumerate() {
            if rep.keep.len() != self.m {
                return Err(Error::coordinator(format!(
                    "shard merge produced {} features, expected {}",
                    rep.keep.len(),
                    self.m
                )));
            }
            // The whole batch shares the shard fan-out; count one sweep.
            record_screen_telemetry(rep, if i == 0 { 1 } else { 0 }, "shard");
        }
        Ok(reports)
    }

    /// Single-target convenience wrapper over [`Self::screen_multi`].
    pub fn screen_all(
        &self,
        rule: RuleKind,
        y: &[f64],
        theta1: &[f64],
        lambda1: f64,
        lambda2: f64,
    ) -> Result<ScreenReport> {
        let mut reps = self.screen_multi(rule, y, theta1, lambda1, &[lambda2])?;
        reps.pop().ok_or_else(|| Error::coordinator("empty shard sweep"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::screening::rule::screen_multi_with;

    #[test]
    fn plan_covers_and_clamps() {
        let nnz = vec![5usize, 1, 1, 9, 2, 2, 2, 8];
        let plan = ShardPlan::build(&nnz, 3);
        assert!(plan.len() <= 3 && !plan.is_empty());
        let mut next = 0;
        for r in &plan.ranges {
            assert_eq!(r.start, next);
            assert!(!r.is_empty());
            next = r.end;
        }
        assert_eq!(next, 8);
        // More shards than features: one shard per feature at most.
        assert!(ShardPlan::build(&nnz, 100).len() <= 8);
        assert!(ShardPlan::build(&[], 4).is_empty());
    }

    #[test]
    fn sharded_matches_unsharded_bitwise() {
        let p = crate::svm::problem::Problem::from_dataset(
            &SynthSpec::text(60, 180, 901).generate(),
        );
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        let l2s = [0.9 * l1, 0.5 * l1];
        let reference = screen_multi_with(
            RuleKind::Paper,
            &p.x,
            &p.y,
            &theta1,
            l1,
            &l2s,
            Some(p.cache()),
        )
        .unwrap();
        let sc = ShardedScreener::build(&p, 4, 2).unwrap();
        assert!(sc.num_shards() >= 2);
        let sharded =
            sc.screen_multi(RuleKind::Paper, &p.y, &theta1, l1, &l2s).unwrap();
        for (a, b) in sharded.iter().zip(&reference) {
            assert_eq!(a.keep, b.keep);
            assert_eq!(a.bounds, b.bounds, "bounds must be bit-identical");
        }
    }

    #[test]
    fn none_rule_and_empty_batch() {
        let p = crate::svm::problem::Problem::from_dataset(
            &SynthSpec::dense(20, 10, 903).generate(),
        );
        let theta1 = p.theta_at_lambda_max().theta();
        let sc = ShardedScreener::build(&p, 3, 1).unwrap();
        assert!(sc
            .screen_multi(RuleKind::Paper, &p.y, &theta1, p.lambda_max(), &[])
            .unwrap()
            .is_empty());
        let rep = sc
            .screen_all(
                RuleKind::None,
                &p.y,
                &theta1,
                p.lambda_max(),
                0.5 * p.lambda_max(),
            )
            .unwrap();
        assert_eq!(rep.n_screened(), 0);
        assert_eq!(rep.keep.len(), 10);
    }

    #[test]
    fn bad_lambdas_error_instead_of_panicking() {
        let p = crate::svm::problem::Problem::from_dataset(
            &SynthSpec::dense(15, 6, 905).generate(),
        );
        let theta1 = p.theta_at_lambda_max().theta();
        let sc = ShardedScreener::build(&p, 2, 1).unwrap();
        let l1 = p.lambda_max();
        assert!(sc
            .screen_multi(RuleKind::Paper, &p.y, &theta1, l1, &[2.0 * l1])
            .is_err());
        assert!(sc
            .screen_multi(RuleKind::Paper, &p.y, &theta1, l1, &[0.0])
            .is_err());
    }
}
