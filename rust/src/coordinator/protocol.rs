//! Hand-rolled JSON encode/decode for the line protocol (no serde in the
//! vendored crate set).
//!
//! Supports the JSON subset the service needs: objects, arrays, f64
//! numbers, strings (with `\` escapes), booleans and null. Every request
//! and response is a single JSON object per line.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (always f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic encoding).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Member lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// f64 view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// str view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Encodes to a single-line JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parses a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::coordinator(format!("trailing input at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(Error::coordinator(format!(
                "expected '{}' at byte {}",
                b as char,
                self.pos.saturating_sub(1)
            )))
        }
    }
    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::coordinator(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::coordinator(format!("unexpected byte at {}", self.pos))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(Error::coordinator("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(Error::coordinator("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::coordinator("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| Error::coordinator("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(Error::coordinator("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(Error::coordinator("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| Error::coordinator("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::coordinator(format!("bad number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("cmd", Json::Str("screen".into())),
            ("lambda2", Json::Num(0.25)),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s = v.encode();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_nested_and_whitespace() {
        let v = parse(" { \"a\" : [1, 2.5, -3e2] , \"b\": {\"c\": \"x\\ny\"} } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(42.0).encode(), "42");
        assert_eq!(Json::Num(0.5).encode(), "0.5");
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\tẞ".into());
        let enc = v.encode();
        assert_eq!(parse(&enc).unwrap(), v);
        // unicode escape decoding
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
