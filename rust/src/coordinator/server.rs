//! The screening service: a TCP line-protocol server exposing the
//! screening rule behind a batching executor.
//!
//! Role in the reproduction: the paper pitches screening as a cheap
//! pre-pass for model selection; the service shape demonstrates the L3
//! coordination — concurrent clients exploring different λ share one
//! dataset-resident process, and the batcher amortizes the O(nnz) stats
//! sweep across requests that target the same dual point (see
//! [`crate::screening::rule::screen_multi`]).
//!
//! ## Protocol (one JSON object per line, response per line)
//!
//! | request | response |
//! |---|---|
//! | `{"cmd":"ping"}` | `{"ok":true,"pong":true}` |
//! | `{"cmd":"info"}` | dataset shape, λ_max, current λ₁ |
//! | `{"cmd":"solve","lambda":x}` | solves at `x`, updates the dual point |
//! | `{"cmd":"screen","lambda2":x}` | batched screening vs the current point |
//! | `{"cmd":"screen","lambda2":x,"indices":true}` | … plus kept indices |
//! | `{"cmd":"stats"}` | live telemetry snapshot: request counters, latency percentiles, batching stats, per-λ screening efficacy |
//! | `{"cmd":"stats","prometheus":true}` | … plus a Prometheus text rendering under `"prometheus"` |
//! | `{"cmd":"trace"}` | drains the trace ring: buffered span/instant records as JSON (plus `dropped` since last drain and cumulative `dropped_total`) |
//! | `{"cmd":"trace","chrome":true}` | … records wrapped as a Chrome trace-event document under `"chrome"` |
//! | `{"cmd":"diag"}` | provenance-ledger summary: recorded/dropped/buffered verdicts, near-miss counts per rule |
//! | `{"cmd":"diag","enable":true}` | toggles the global ledger on/off before summarizing |
//! | `{"cmd":"diag","feature":17}` | … plus the full verdict history of feature 17 under `"feature_history"` |
//! | `{"cmd":"diag","top":5}` | … plus the 5 closest near-miss verdicts under `"near_misses"` |
//! | `{"cmd":"diag","solver":true}` | … plus recent convergence summaries (gap traces, stalls, anomalies) under `"solves"` |
//! | `{"cmd":"quit"}` | closes the connection |
//!
//! Every response carries `"ok"`; errors come back as
//! `{"ok":false,"error":"..."}`.
//!
//! ## Telemetry
//!
//! Every request is timed into the global registry
//! ([`crate::telemetry`]): latency histograms `server.screen.seconds`
//! / `server.solve.seconds` / `server.request.seconds`, counters
//! `server.requests` / `server.connections`, and batch-coalescing
//! stats `server.batches` / `server.batch.coalesced` plus the
//! `server.batch.size` histogram. `{"cmd":"stats"}` exposes all of it
//! over the wire; `PALLAS_LOG=debug` traces per-request handling on
//! stderr. With sharding on (`ServerConfig::shards > 1`), the sweep
//! additionally reports per-shard `coordinator.shard.<k>.{kept,
//! screened,seconds}` and the shard-shape gauges (see
//! [`crate::coordinator::shard`]).
//!
//! ## Hardening
//!
//! The server is built to survive its own bugs: connection handlers run
//! under `catch_unwind` (a panic costs one connection, counted in
//! `server.handler_panics`, never a pool worker), the dual-state mutex
//! recovers from poisoning ([`lock_state`]), and degenerate datasets
//! (non-positive/non-finite `lambda_max`) are rejected at
//! [`ScreeningServer::start`] instead of panicking per-request.

use crate::coordinator::batcher::{next_batch, BatchItem, BatchPolicy};
use crate::coordinator::pool::ThreadPool;
use crate::coordinator::protocol::{parse, Json};
use crate::coordinator::shard::ShardedScreener;
use crate::error::{Error, Result};
use crate::screening::rule::{screen_multi_with, RuleKind};
use crate::solver::api::{solve, SolveOptions, SolverKind};
use crate::svm::problem::Problem;
use crate::telemetry::{self, Counter, Histogram};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub addr: String,
    /// Connection-handler threads.
    pub workers: usize,
    /// Batching policy for screen requests.
    pub batch: BatchPolicy,
    /// Screening rule.
    pub rule: RuleKind,
    /// Solver options for `solve` requests.
    pub solve: SolveOptions,
    /// Feature shards for the batch executor (`--shards`/`PALLAS_SHARDS`).
    /// `> 1` builds a [`ShardedScreener`]: per-shard long-lived gathered
    /// columns + remapped cache, per-shard metrics, bit-identical kept
    /// sets. `<= 1` keeps the unsharded whole-matrix sweep (no duplicate
    /// storage).
    pub shards: usize,
    /// Test-only fault injection: enables the `{"cmd":"panic"}` request,
    /// which panics inside the handler *while holding the state lock* —
    /// exercising both the pool's panic containment and the poisoned-
    /// mutex recovery. Never enable outside tests.
    pub fault_injection: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            batch: BatchPolicy::default(),
            rule: RuleKind::Paper,
            solve: SolveOptions::default(),
            shards: 1,
            fault_injection: false,
        }
    }
}

/// The current dual point the server screens against.
#[derive(Clone)]
struct DualState {
    lambda1: f64,
    theta1: Arc<Vec<f64>>,
}

struct ScreenJob {
    lambda2: f64,
    want_indices: bool,
    state: DualState,
    reply: Sender<Json>,
}

impl BatchItem for ScreenJob {
    /// Inline struct plus the `Arc`'d dual point the queued job keeps
    /// alive. Counting the full θ₁ vector per job is an upper bound
    /// (coalesced jobs share one allocation), but it is the memory the
    /// queue *pins*: the vector cannot be freed while any job holds it.
    fn payload_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.state.theta1.len() * std::mem::size_of::<f64>()
    }
}

/// Service metrics (monotone counters).
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests served, by type.
    pub screens: AtomicU64,
    /// Total batches flushed.
    pub batches: AtomicU64,
    /// Solve requests served.
    pub solves: AtomicU64,
}

/// Cached handles into the global telemetry registry so the hot path
/// never touches the registry's name map (one `Arc` deref per event).
struct Tele {
    connections: Arc<Counter>,
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    coalesced: Arc<Counter>,
    batch_size: Arc<Histogram>,
    screen_seconds: Arc<Histogram>,
    solve_seconds: Arc<Histogram>,
    request_seconds: Arc<Histogram>,
    handler_panics: Arc<Counter>,
}

impl Tele {
    fn new() -> Self {
        let t = telemetry::global();
        Tele {
            connections: t.counter("server.connections"),
            requests: t.counter("server.requests"),
            batches: t.counter("server.batches"),
            coalesced: t.counter("server.batch.coalesced"),
            batch_size: t.histogram("server.batch.size"),
            screen_seconds: t.histogram("server.screen.seconds"),
            solve_seconds: t.histogram("server.solve.seconds"),
            request_seconds: t.histogram("server.request.seconds"),
            handler_panics: t.counter("server.handler_panics"),
        }
    }
}

struct Shared {
    problem: Problem,
    state: Mutex<DualState>,
    rule: RuleKind,
    solve_opts: SolveOptions,
    metrics: Metrics,
    tele: Tele,
    stop: AtomicBool,
    /// Sharded batch executor (`cfg.shards > 1`); `None` keeps the
    /// unsharded whole-matrix sweep without duplicating column storage.
    screener: Option<ShardedScreener>,
    fault_injection: bool,
}

/// Locks the dual state, recovering from poisoning. A handler that
/// panicked mid-update can only have left `DualState` consistent — both
/// fields are written together under the lock and the struct has no
/// invariant spanning the write — so inheriting the last value is safe,
/// and one crashed handler must not wedge every future connection (the
/// pre-recovery behavior: every later `.lock().unwrap()` panicked too,
/// killing its pool worker, until no workers remained).
fn lock_state(shared: &Shared) -> std::sync::MutexGuard<'_, DualState> {
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// A running screening service.
pub struct ScreeningServer {
    /// The bound address (resolves port 0).
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    exec_handle: Option<std::thread::JoinHandle<()>>,
}

impl ScreeningServer {
    /// Starts the service on `cfg.addr` with the given problem.
    ///
    /// Degenerate data is rejected here, not discovered as a panic in
    /// some later handler: a non-positive or non-finite `lambda_max`
    /// (all-zero features, NaN labels) means no λ-grid and no dual point
    /// exist, so `start` returns an [`Error`] instead of serving a
    /// process that panics on every `info`/`screen`.
    pub fn start(problem: Problem, cfg: ServerConfig) -> Result<Self> {
        let lmax = problem.lambda_max();
        if !(lmax.is_finite() && lmax > 0.0) {
            return Err(Error::data(format!(
                "cannot serve '{}': lambda_max = {lmax} (expected positive \
                 and finite; is the dataset all-zero or mislabeled?)",
                problem.name
            )));
        }
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::coordinator(format!("bind {}: {e}", cfg.addr)))?;
        let addr = listener.local_addr()?;

        // Shard the feature axis before moving the problem: each shard
        // gathers its columns + remapped cache once, then reuses them
        // for every batch this server ever screens.
        let screener = if cfg.shards > 1 {
            Some(ShardedScreener::build(&problem, cfg.shards, cfg.workers)?)
        } else {
            None
        };
        let init = DualState {
            lambda1: lmax,
            theta1: Arc::new(problem.theta_at_lambda_max().theta()),
        };
        let shared = Arc::new(Shared {
            problem,
            state: Mutex::new(init),
            rule: cfg.rule,
            solve_opts: cfg.solve,
            metrics: Metrics::default(),
            tele: Tele::new(),
            stop: AtomicBool::new(false),
            screener,
            fault_injection: cfg.fault_injection,
        });

        // Screening executor: drains the job channel in batches.
        let (job_tx, job_rx) = channel::<ScreenJob>();
        let exec_shared = Arc::clone(&shared);
        let policy = cfg.batch;
        let exec_handle = std::thread::spawn(move || loop {
            let batch = next_batch(&job_rx, &policy);
            if batch.is_empty() {
                break; // channel closed
            }
            exec_shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
            exec_shared.tele.batches.inc();
            run_screen_batch(&exec_shared, batch);
        });

        // Accept loop on the handler pool.
        let accept_shared = Arc::clone(&shared);
        let pool = ThreadPool::new(cfg.workers);
        let accept_handle = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if accept_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                // One JSON line per request/response: disable Nagle or
                // every round trip eats a delayed-ACK (~40-90ms observed;
                // EXPERIMENTS.md §Perf P4).
                let _ = stream.set_nodelay(true);
                let shared = Arc::clone(&accept_shared);
                let tx = job_tx.clone();
                pool.execute(move || {
                    // Contain handler panics: an uncaught unwind kills
                    // the pool worker permanently, so `workers` panics
                    // would leave the server accepting connections it
                    // can never serve. The connection is lost (client
                    // sees EOF); the worker survives for the next one.
                    let outcome = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            let _ = handle_connection(stream, &shared, &tx);
                        }),
                    );
                    if outcome.is_err() {
                        shared.tele.handler_panics.inc();
                        crate::tele_debug!(
                            "server",
                            "connection handler panicked; worker recovered"
                        );
                    }
                });
            }
            // pool drops here, joining handlers; job_tx clones die with them
            drop(job_tx);
        });

        Ok(ScreeningServer {
            addr,
            shared,
            accept_handle: Some(accept_handle),
            exec_handle: Some(exec_handle),
        })
    }

    /// Metrics snapshot: `(screens, batches, solves)`.
    pub fn metrics(&self) -> (u64, u64, u64) {
        (
            self.shared.metrics.screens.load(Ordering::Relaxed),
            self.shared.metrics.batches.load(Ordering::Relaxed),
            self.shared.metrics.solves.load(Ordering::Relaxed),
        )
    }

    /// Stops accepting and joins the background threads.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.exec_handle.take() {
            let _ = h.join();
        }
    }
}

fn run_screen_batch(shared: &Shared, batch: Vec<ScreenJob>) {
    // Group by identical dual point (Arc pointer + lambda1 bits): each
    // group shares one stats-panel sweep.
    let mut groups: Vec<(DualState, Vec<ScreenJob>)> = Vec::new();
    for job in batch {
        match groups.iter_mut().find(|(st, _)| {
            Arc::ptr_eq(&st.theta1, &job.state.theta1)
                && st.lambda1.to_bits() == job.state.lambda1.to_bits()
        }) {
            Some((_, jobs)) => jobs.push(job),
            None => groups.push((job.state.clone(), vec![job])),
        }
    }
    for (state, jobs) in groups {
        let batch_size = jobs.len();
        shared.tele.batch_size.record(batch_size as f64);
        // "Coalesced" = requests that piggybacked on another request's
        // stats sweep instead of paying for their own.
        shared.tele.coalesced.add(batch_size as u64 - 1);
        if batch_size > 1 {
            crate::tele_debug!(
                "server.batch",
                "coalesced {batch_size} screen request(s) at lambda1 {:.4e}",
                state.lambda1
            );
        }
        let lambda2s: Vec<f64> = jobs.iter().map(|j| j.lambda2).collect();
        // Span: the group's shared sweep lands in `server.batch.seconds`.
        let span = crate::telemetry::Span::enter_labeled(
            "server.batch",
            Some(format!("{batch_size} request(s)")),
        );
        // The problem cache makes each batched sweep a single θ-dot per
        // feature (λ-independent stats are shared across all requests).
        // With sharding on, the sweep fans out across per-shard reduced
        // problems instead — same arithmetic, bit-identical kept sets.
        let result = match &shared.screener {
            Some(sc) => sc.screen_multi(
                shared.rule,
                &shared.problem.y,
                &state.theta1,
                state.lambda1,
                &lambda2s,
            ),
            None => screen_multi_with(
                shared.rule,
                &shared.problem.x,
                &shared.problem.y,
                &state.theta1,
                state.lambda1,
                &lambda2s,
                Some(shared.problem.cache()),
            ),
        };
        drop(span);
        match result {
            Ok(reports) => {
                for (job, rep) in jobs.into_iter().zip(reports) {
                    shared.metrics.screens.fetch_add(1, Ordering::Relaxed);
                    let mut fields = vec![
                        ("ok", Json::Bool(true)),
                        ("kept", Json::Num((rep.keep.len() - rep.n_screened()) as f64)),
                        ("screened", Json::Num(rep.n_screened() as f64)),
                        ("rejection", Json::Num(rep.rejection_ratio())),
                        ("seconds", Json::Num(rep.seconds)),
                        ("batch_size", Json::Num(batch_size as f64)),
                        ("lambda1", Json::Num(rep.lambda1)),
                        ("lambda2", Json::Num(rep.lambda2)),
                    ];
                    if job.want_indices {
                        fields.push((
                            "indices",
                            Json::Arr(
                                rep.kept_indices()
                                    .into_iter()
                                    .map(|j| Json::Num(j as f64))
                                    .collect(),
                            ),
                        ));
                    }
                    let _ = job.reply.send(Json::obj(fields));
                }
            }
            Err(e) => {
                for job in jobs {
                    let _ = job.reply.send(err_json(&e.to_string()));
                }
            }
        }
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

fn handle_connection(
    stream: TcpStream,
    shared: &Shared,
    job_tx: &Sender<ScreenJob>,
) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    shared.tele.connections.inc();
    crate::tele_debug!("server", "connection from {peer}");
    // Bounded reads so shutdown can interrupt idle connections: the
    // handler re-checks the stop flag every timeout tick. Without this,
    // ThreadPool::drop (inside the accept thread) joins a worker that is
    // blocked forever on a silent client — a shutdown deadlock.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Persistent accumulator: a timeout can interrupt read_line mid-line
    // with partial bytes already appended, so the buffer lives across
    // iterations and is only consumed at a complete newline.
    let mut acc = String::new();
    loop {
        let start_len = acc.len();
        match reader.read_line(&mut acc) {
            Ok(0) => break, // EOF
            Ok(_) if acc.ends_with('\n') => {}
            Ok(_) => continue, // partial line (EOF race); keep reading
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                let _ = start_len;
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        let line = std::mem::take(&mut acc);
        if line.trim().is_empty() {
            continue;
        }
        let line = line.trim().to_string();
        let response = match parse(&line) {
            Ok(req) => {
                let cmd = req.get("cmd").and_then(|c| c.as_str()).unwrap_or("");
                if cmd == "quit" {
                    break;
                }
                dispatch(cmd, &req, shared, job_tx)
            }
            Err(e) => err_json(&format!("bad request: {e}")),
        };
        writeln!(writer, "{}", response.encode())?;
    }
    Ok(())
}

/// Times a request through [`dispatch_inner`], recording per-command
/// latency histograms and the `server.requests` counter.
fn dispatch(cmd: &str, req: &Json, shared: &Shared, job_tx: &Sender<ScreenJob>) -> Json {
    let t0 = std::time::Instant::now();
    let response = dispatch_inner(cmd, req, shared, job_tx);
    let secs = t0.elapsed().as_secs_f64();
    shared.tele.requests.inc();
    let hist = match cmd {
        "screen" => &shared.tele.screen_seconds,
        "solve" => &shared.tele.solve_seconds,
        _ => &shared.tele.request_seconds,
    };
    hist.record(secs);
    crate::tele_debug!(
        "server",
        "{cmd} handled in {}",
        crate::report::timer::fmt_duration(secs)
    );
    response
}

fn dispatch_inner(cmd: &str, req: &Json, shared: &Shared, job_tx: &Sender<ScreenJob>) -> Json {
    match cmd {
        "ping" => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        "info" => {
            let p = &shared.problem;
            let st = lock_state(shared);
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("name", Json::Str(p.name.clone())),
                ("n", Json::Num(p.n() as f64)),
                ("m", Json::Num(p.m() as f64)),
                ("lambda_max", Json::Num(p.lambda_max())),
                ("lambda1", Json::Num(st.lambda1)),
                ("rule", Json::Str(shared.rule.name().into())),
            ])
        }
        "solve" => {
            let lambda = match req.get("lambda").and_then(|v| v.as_f64()) {
                Some(v) if v > 0.0 => v,
                _ => return err_json("solve requires positive \"lambda\""),
            };
            let p = &shared.problem;
            match solve(SolverKind::Cd, &p.x, &p.y, lambda, None, &shared.solve_opts) {
                Ok(rep) => {
                    let theta = crate::svm::dual::theta_from_primal(
                        &p.x, &p.y, &rep.w, rep.b, lambda,
                    );
                    let mut st = lock_state(shared);
                    st.lambda1 = lambda;
                    st.theta1 = Arc::new(theta);
                    drop(st);
                    shared.metrics.solves.fetch_add(1, Ordering::Relaxed);
                    Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("nnz", Json::Num(rep.nnz() as f64)),
                        ("iterations", Json::Num(rep.iterations as f64)),
                        ("rel_gap", Json::Num(rep.gap.rel_gap)),
                        ("seconds", Json::Num(rep.seconds)),
                        ("converged", Json::Bool(rep.converged)),
                    ])
                }
                Err(e) => err_json(&e.to_string()),
            }
        }
        "screen" => {
            let lambda2 = match req.get("lambda2").and_then(|v| v.as_f64()) {
                Some(v) if v > 0.0 => v,
                _ => return err_json("screen requires positive \"lambda2\""),
            };
            let state = lock_state(shared).clone();
            if lambda2 >= state.lambda1 {
                return err_json(&format!(
                    "lambda2 {lambda2} must be < current lambda1 {}",
                    state.lambda1
                ));
            }
            let want_indices = matches!(req.get("indices"), Some(Json::Bool(true)));
            let (reply_tx, reply_rx) = channel();
            if job_tx
                .send(ScreenJob { lambda2, want_indices, state, reply: reply_tx })
                .is_err()
            {
                return err_json("executor unavailable");
            }
            reply_rx
                .recv()
                .unwrap_or_else(|_| err_json("executor dropped the request"))
        }
        "stats" => {
            let snap = telemetry::global().snapshot();
            let m = &shared.metrics;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("screens", Json::Num(m.screens.load(Ordering::Relaxed) as f64)),
                ("batches", Json::Num(m.batches.load(Ordering::Relaxed) as f64)),
                ("solves", Json::Num(m.solves.load(Ordering::Relaxed) as f64)),
                ("metrics", snap.to_json()),
            ];
            if matches!(req.get("prometheus"), Some(Json::Bool(true))) {
                fields.push((
                    "prometheus",
                    Json::Str(crate::report::prometheus::render(&snap)),
                ));
            }
            Json::obj(fields)
        }
        "trace" => {
            // Drain: trace records are delivered at most once, so a
            // periodic scraper sees each span exactly one time.
            let ring = crate::telemetry::trace::recorder();
            let dropped = ring.dropped();
            let records = ring.drain();
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("count", Json::Num(records.len() as f64)),
                ("dropped", Json::Num(dropped as f64)),
                ("dropped_total", Json::Num(ring.dropped_total() as f64)),
            ];
            if matches!(req.get("chrome"), Some(Json::Bool(true))) {
                fields.push(("chrome", crate::telemetry::trace::chrome_trace(&records)));
            } else {
                fields.push((
                    "records",
                    Json::Arr(records.iter().map(|r| r.to_json()).collect()),
                ));
            }
            Json::obj(fields)
        }
        "diag" => {
            let ledger = crate::diag::ledger::global();
            if let Some(Json::Bool(b)) = req.get("enable") {
                ledger.set_enabled(*b);
            }
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("ledger", ledger.summary().to_json()),
            ];
            if let Some(j) = req.get("feature").and_then(|v| v.as_f64()) {
                let history = ledger.feature_history(j as usize);
                fields.push((
                    "feature_history",
                    Json::Arr(history.iter().map(|v| v.to_json()).collect()),
                ));
            }
            if let Some(n) = req.get("top").and_then(|v| v.as_f64()) {
                let top = ledger.top_near_misses(n.max(0.0) as usize);
                fields.push((
                    "near_misses",
                    Json::Arr(top.iter().map(|v| v.to_json()).collect()),
                ));
            }
            if matches!(req.get("solver"), Some(Json::Bool(true))) {
                let log = crate::diag::convergence::log_snapshot();
                let tail = log.len().saturating_sub(16);
                fields.push((
                    "solves",
                    Json::Arr(log[tail..].iter().map(|s| s.to_json()).collect()),
                ));
            }
            Json::obj(fields)
        }
        // Fault injection (ServerConfig::fault_injection, tests only):
        // panic while holding the state lock, so both the pool's panic
        // containment and the poisoned-mutex recovery get exercised by
        // one request. Unknown cmd when injection is off.
        "panic" if shared.fault_injection => {
            let _guard = lock_state(shared);
            panic!("injected fault: handler panic while holding the state lock");
        }
        other => err_json(&format!("unknown cmd {other:?}")),
    }
}

/// Minimal blocking client used by tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connects to a running service.
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::coordinator(format!("connect: {e}")))?;
        let _ = stream.set_nodelay(true); // line protocol: no Nagle (Perf P4)
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Sends one request and reads one response.
    pub fn request(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{}", req.encode())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(Error::coordinator("server closed connection"));
        }
        parse(line.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn start_test_server() -> ScreeningServer {
        let p = Problem::from_dataset(&SynthSpec::text(50, 120, 201).generate());
        ScreeningServer::start(p, ServerConfig::default()).unwrap()
    }

    #[test]
    fn ping_info_roundtrip() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        let pong = c.request(&Json::obj(vec![("cmd", Json::Str("ping".into()))])).unwrap();
        assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
        let info = c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        assert_eq!(info.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(info.get("m").unwrap().as_f64(), Some(120.0));
        server.shutdown();
    }

    #[test]
    fn screen_request_flows_through_batcher() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        let info = c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.8 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let kept = rep.get("kept").unwrap().as_f64().unwrap();
        let screened = rep.get("screened").unwrap().as_f64().unwrap();
        assert_eq!(kept + screened, 120.0);
        assert!(screened > 0.0, "screening should fire at 0.8 lmax");
        let (screens, batches, _) = server.metrics();
        assert_eq!(screens, 1);
        assert!(batches >= 1);
        server.shutdown();
    }

    #[test]
    fn solve_updates_dual_point_and_indices_work() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        let info = c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();
        let sol = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("solve".into())),
                ("lambda", Json::Num(0.6 * lmax)),
            ]))
            .unwrap();
        assert_eq!(sol.get("ok"), Some(&Json::Bool(true)), "{sol:?}");
        assert_eq!(sol.get("converged"), Some(&Json::Bool(true)));
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.5 * lmax)),
                ("indices", Json::Bool(true)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let idx = rep.get("indices").unwrap().as_arr().unwrap();
        assert_eq!(idx.len() as f64, rep.get("kept").unwrap().as_f64().unwrap());
        server.shutdown();
    }

    #[test]
    fn concurrent_screens_batch_together() {
        let p = Problem::from_dataset(&SynthSpec::text(60, 400, 203).generate());
        let mut cfg = ServerConfig::default();
        cfg.batch = BatchPolicy {
            max_batch: 8,
            window: std::time::Duration::from_millis(50),
        };
        let server = ScreeningServer::start(p, cfg).unwrap();
        let addr = server.addr;
        let lmax = {
            let mut c = Client::connect(addr).unwrap();
            let info =
                c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
            info.get("lambda_max").unwrap().as_f64().unwrap()
        };
        let handles: Vec<_> = (0..6)
            .map(|k| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let rep = c
                        .request(&Json::obj(vec![
                            ("cmd", Json::Str("screen".into())),
                            ("lambda2", Json::Num((0.5 + 0.05 * k as f64) * lmax)),
                        ]))
                        .unwrap();
                    rep.get("batch_size").unwrap().as_f64().unwrap()
                })
            })
            .collect();
        let sizes: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // At least some requests should have shared a batch.
        assert!(sizes.iter().any(|&s| s > 1.0), "batch sizes {sizes:?}");
        server.shutdown();
    }

    #[test]
    fn stats_command_reports_counters_and_latency() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        let info = c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.7 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let stats = c.request(&Json::obj(vec![("cmd", Json::Str("stats".into()))])).unwrap();
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats:?}");
        assert_eq!(stats.get("screens").unwrap().as_f64(), Some(1.0));
        let metrics = stats.get("metrics").unwrap();
        let counters = metrics.get("counters").unwrap();
        // Registry is process-global, so only monotone lower bounds hold.
        assert!(
            counters.get("server.requests").unwrap().as_f64().unwrap() >= 2.0,
            "{counters:?}"
        );
        let hists = metrics.get("histograms").unwrap();
        let screen_h = hists.get("server.screen.seconds").unwrap();
        assert!(screen_h.get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(screen_h.get("p99").unwrap().as_f64().unwrap() >= 0.0);
        // Prometheus rendering is opt-in.
        assert!(stats.get("prometheus").is_none());
        let stats = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("stats".into())),
                ("prometheus", Json::Bool(true)),
            ]))
            .unwrap();
        let text = stats.get("prometheus").unwrap().as_str().unwrap();
        assert!(text.contains("server_requests_total"), "{text}");
        assert!(text.contains("quantile=\"0.99\""), "{text}");
        server.shutdown();
    }

    #[test]
    fn trace_command_drains_ring_over_the_wire() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        let info = c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();
        // One screen -> at least one server.batch span lands in the ring
        // before the reply is sent.
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.7 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let trace =
            c.request(&Json::obj(vec![("cmd", Json::Str("trace".into()))])).unwrap();
        assert_eq!(trace.get("ok"), Some(&Json::Bool(true)), "{trace:?}");
        let records = trace.get("records").unwrap().as_arr().unwrap();
        assert!(
            records.len() as f64 == trace.get("count").unwrap().as_f64().unwrap()
        );
        assert!(
            records.iter().any(|r| {
                r.get("name").and_then(|n| n.as_str()) == Some("server.batch")
            }),
            "expected a server.batch span in {records:?}"
        );
        // Chrome-document variant: well-formed even on an empty ring.
        let chrome = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("trace".into())),
                ("chrome", Json::Bool(true)),
            ]))
            .unwrap();
        assert_eq!(chrome.get("ok"), Some(&Json::Bool(true)));
        assert!(chrome.get("records").is_none());
        let doc = chrome.get("chrome").unwrap();
        assert!(doc.get("traceEvents").unwrap().as_arr().is_some());
        server.shutdown();
    }

    #[test]
    fn diag_command_toggles_ledger_and_answers_queries() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        // Enable the global ledger over the wire, screen once, then ask
        // for provenance. The ledger is process-global, so assertions
        // are tolerant of concurrent recorders in other tests.
        let diag = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("diag".into())),
                ("enable", Json::Bool(true)),
            ]))
            .unwrap();
        assert_eq!(diag.get("ok"), Some(&Json::Bool(true)), "{diag:?}");
        let ledger = diag.get("ledger").unwrap();
        assert_eq!(ledger.get("enabled"), Some(&Json::Bool(true)));
        let info = c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.7 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        let diag = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("diag".into())),
                ("feature", Json::Num(0.0)),
                ("top", Json::Num(3.0)),
                ("solver", Json::Bool(true)),
            ]))
            .unwrap();
        assert_eq!(diag.get("ok"), Some(&Json::Bool(true)), "{diag:?}");
        let summary = diag.get("ledger").unwrap();
        assert!(summary.get("recorded").unwrap().as_f64().unwrap() >= 120.0);
        let history = diag.get("feature_history").unwrap().as_arr().unwrap();
        assert!(!history.is_empty(), "feature 0 should have a verdict");
        assert_eq!(history[0].get("feature").unwrap().as_f64(), Some(0.0));
        let top = diag.get("near_misses").unwrap().as_arr().unwrap();
        assert!(top.len() <= 3);
        assert!(diag.get("solves").unwrap().as_arr().is_some());
        // Disable again so other tests see the default-off ledger.
        let diag = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("diag".into())),
                ("enable", Json::Bool(false)),
            ]))
            .unwrap();
        assert_eq!(
            diag.get("ledger").unwrap().get("enabled"),
            Some(&Json::Bool(false))
        );
        server.shutdown();
    }

    #[test]
    fn stats_command_guards_nan_gauges_and_empty_histograms() {
        let server = start_test_server();
        // Poison the global registry the way a buggy producer would:
        // a NaN gauge and a histogram nobody ever recorded into.
        telemetry::global().gauge("server.test.nan_gauge").set(f64::NAN);
        let _ = telemetry::global().histogram("server.test.empty_hist");
        let mut c = Client::connect(server.addr).unwrap();
        let stats = c.request(&Json::obj(vec![
            ("cmd", Json::Str("stats".into())),
            ("prometheus", Json::Bool(true)),
        ]));
        let stats = stats.unwrap();
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats:?}");
        let metrics = stats.get("metrics").unwrap();
        // Non-finite gauges must encode as null, never as bare NaN
        // (which would corrupt the JSON line protocol).
        assert_eq!(
            metrics.get("gauges").unwrap().get("server.test.nan_gauge"),
            Some(&Json::Null)
        );
        let hist = metrics
            .get("histograms")
            .unwrap()
            .get("server.test.empty_hist")
            .unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(0.0));
        // The Prometheus rendering must survive both edge cases too.
        let text = stats.get("prometheus").unwrap().as_str().unwrap();
        assert!(text.contains("server_test_nan_gauge"), "{text}");
        assert!(text.contains("server_test_empty_hist"), "{text}");
        server.shutdown();
    }

    #[test]
    fn handler_panic_leaves_server_responsive() {
        let p = Problem::from_dataset(&SynthSpec::text(50, 120, 207).generate());
        let cfg = ServerConfig {
            workers: 2,
            fault_injection: true,
            ..ServerConfig::default()
        };
        let server = ScreeningServer::start(p, cfg).unwrap();
        let panics = telemetry::global().counter("server.handler_panics");
        let before = panics.get();
        // Panic more times than there are pool workers while holding the
        // state lock: without catch_unwind every worker dies and without
        // poisoning recovery every later lock().unwrap() panics too.
        for _ in 0..4 {
            let mut c = Client::connect(server.addr).unwrap();
            let r = c.request(&Json::obj(vec![("cmd", Json::Str("panic".into()))]));
            assert!(r.is_err(), "panicking handler should drop its connection");
        }
        // The EOF races the worker's unwind; wait for all four recoveries.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while panics.get() < before + 4 {
            assert!(
                std::time::Instant::now() < deadline,
                "handler panics not recorded: {} < {}",
                panics.get(),
                before + 4
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        // Every command class still works against the recovered server.
        let mut c = Client::connect(server.addr).unwrap();
        let pong =
            c.request(&Json::obj(vec![("cmd", Json::Str("ping".into()))])).unwrap();
        assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
        let info =
            c.request(&Json::obj(vec![("cmd", Json::Str("info".into()))])).unwrap();
        assert_eq!(info.get("ok"), Some(&Json::Bool(true)), "{info:?}");
        let lmax = info.get("lambda_max").unwrap().as_f64().unwrap();
        let sol = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("solve".into())),
                ("lambda", Json::Num(0.7 * lmax)),
            ]))
            .unwrap();
        assert_eq!(sol.get("ok"), Some(&Json::Bool(true)), "{sol:?}");
        let rep = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(0.5 * lmax)),
            ]))
            .unwrap();
        assert_eq!(rep.get("ok"), Some(&Json::Bool(true)), "{rep:?}");
        server.shutdown();
    }

    #[test]
    fn panic_command_requires_fault_injection() {
        let server = start_test_server(); // fault_injection: false
        let mut c = Client::connect(server.addr).unwrap();
        let r = c.request(&Json::obj(vec![("cmd", Json::Str("panic".into()))])).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r:?}");
        server.shutdown();
    }

    #[test]
    fn degenerate_lambda_max_rejected_at_start() {
        // All-zero features: lambda_max = 0, no dual point exists.
        let p = Problem::new(
            "degenerate",
            crate::data::FeatureData::Dense(crate::data::dense::DenseMatrix::zeros(
                10, 4,
            )),
            vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0],
        );
        let err = ScreeningServer::start(p, ServerConfig::default());
        assert!(err.is_err(), "zero lambda_max must be rejected at start");
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("lambda_max"), "{msg}");
    }

    #[test]
    fn malformed_requests_get_errors() {
        let server = start_test_server();
        let mut c = Client::connect(server.addr).unwrap();
        let r = c.request(&Json::obj(vec![("cmd", Json::Str("bogus".into()))])).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = c
            .request(&Json::obj(vec![("cmd", Json::Str("screen".into()))]))
            .unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // lambda2 >= lambda1 rejected
        let r = c
            .request(&Json::obj(vec![
                ("cmd", Json::Str("screen".into())),
                ("lambda2", Json::Num(1e12)),
            ]))
            .unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        server.shutdown();
    }
}
