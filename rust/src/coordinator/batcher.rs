//! Request batching with a size/deadline policy.
//!
//! The screening service amortizes the O(nnz) stats-panel sweep across
//! concurrent requests that share the same source dual point (θ₁): the
//! batcher collects requests for up to `max_batch` items or
//! `window` (whichever first), and the executor screens the whole batch
//! in one pass via [`crate::screening::rule::screen_multi`].
//!
//! Every flushed batch reports its item count and in-memory payload
//! size into count-scale histograms (`coordinator.batch.items`,
//! `coordinator.batch.bytes`) so `{"cmd":"stats"}` shows how well the
//! amortization is working under real load.

use crate::telemetry::{self, BucketSpec};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// A batchable request that knows its real in-memory footprint.
///
/// The default counts only the inline struct bytes; requests that carry
/// heap payloads (request vectors, shared dual points held alive by the
/// queue) override [`BatchItem::payload_bytes`] so
/// `coordinator.batch.bytes` reflects actual queue memory instead of
/// underreporting by `size_of::<R>()`.
pub trait BatchItem {
    /// Bytes this request pins in memory while queued: inline struct
    /// size plus any heap it owns or keeps alive.
    fn payload_bytes(&self) -> usize {
        std::mem::size_of_val(self)
    }
}

// Test/bench item types used through the batcher are plain scalars.
impl BatchItem for i32 {}
impl BatchItem for u64 {}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, window: Duration::from_millis(5) }
    }
}

/// Blocks for the next batch: waits indefinitely for the first item,
/// then drains until the policy triggers. Returns an empty vec when the
/// channel is closed and drained.
pub fn next_batch<R: BatchItem>(rx: &Receiver<R>, policy: &BatchPolicy) -> Vec<R> {
    let mut batch = Vec::new();
    // Block for the first item.
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return batch,
    }
    let deadline = Instant::now() + policy.window;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    record_batch_telemetry(&batch);
    batch
}

/// Meters one flushed batch: item count plus payload bytes summed from
/// each item's [`BatchItem::payload_bytes`], so heap-backed requests
/// (e.g. screen jobs holding an `Arc`'d dual point) are not
/// underreported as `len * size_of::<R>()`.
fn record_batch_telemetry<R: BatchItem>(batch: &[R]) {
    if batch.is_empty() {
        return;
    }
    let tele = telemetry::global();
    tele.histogram_with("coordinator.batch.items", BucketSpec::COUNTS)
        .record(batch.len() as f64);
    let bytes: usize = batch.iter().map(|r| r.payload_bytes()).sum();
    tele.histogram_with("coordinator.batch.bytes", BucketSpec::COUNTS)
        .record(bytes as f64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn flushes_on_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_secs(10) };
        let b = next_batch(&rx, &policy);
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, &policy);
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_on_window() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let policy = BatchPolicy { max_batch: 100, window: Duration::from_millis(20) };
        let b = next_batch(&rx, &policy);
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn empty_on_disconnect() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default());
        assert!(b.is_empty());
    }

    #[test]
    fn batches_record_count_scale_histograms() {
        let (tx, rx) = channel();
        for i in 0..6u64 {
            tx.send(i).unwrap();
        }
        let tele = crate::telemetry::global();
        let before = tele.histogram("coordinator.batch.items").count();
        let policy = BatchPolicy { max_batch: 6, window: Duration::from_secs(5) };
        let b = next_batch(&rx, &policy);
        assert_eq!(b.len(), 6);
        // Global histogram: sibling tests may record concurrently.
        let items = tele.histogram("coordinator.batch.items");
        assert!(items.count() >= before + 1);
        // The histograms must carry the count-scale bucket layout: a
        // seconds-scale histogram would clamp a 6-item batch badly.
        assert_eq!(items.spec(), crate::telemetry::BucketSpec::COUNTS);
        let bytes = tele.histogram("coordinator.batch.bytes").snapshot();
        assert!(bytes.max >= (6 * std::mem::size_of::<u64>()) as f64);
    }

    #[test]
    fn payload_bytes_sums_heap_backing() {
        struct Req(Vec<u8>);
        impl BatchItem for Req {
            fn payload_bytes(&self) -> usize {
                std::mem::size_of::<Self>() + self.0.capacity()
            }
        }
        let (tx, rx) = channel();
        tx.send(Req(vec![0u8; 4096])).unwrap();
        tx.send(Req(vec![0u8; 4096])).unwrap();
        let tele = crate::telemetry::global();
        let policy = BatchPolicy { max_batch: 2, window: Duration::from_secs(5) };
        let b = next_batch(&rx, &policy);
        assert_eq!(b.len(), 2);
        // The shallow size would be 2 * size_of::<Req>() (~48 bytes);
        // the hook must surface the 8 KiB of heap the queue pinned.
        let bytes = tele.histogram("coordinator.batch.bytes").snapshot();
        assert!(
            bytes.max >= (2 * (std::mem::size_of::<Req>() + 4096)) as f64,
            "batch.bytes max {} misses heap payload",
            bytes.max
        );
    }

    #[test]
    fn late_arrivals_within_window_join() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        let policy = BatchPolicy { max_batch: 10, window: Duration::from_millis(50) };
        let b = next_batch(&rx, &policy);
        handle.join().unwrap();
        assert_eq!(b.len(), 2);
    }
}
