//! Request batching with a size/deadline policy.
//!
//! The screening service amortizes the O(nnz) stats-panel sweep across
//! concurrent requests that share the same source dual point (θ₁): the
//! batcher collects requests for up to `max_batch` items or
//! `window` (whichever first), and the executor screens the whole batch
//! in one pass via [`crate::screening::rule::screen_multi`].

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, window: Duration::from_millis(5) }
    }
}

/// Blocks for the next batch: waits indefinitely for the first item,
/// then drains until the policy triggers. Returns an empty vec when the
/// channel is closed and drained.
pub fn next_batch<R>(rx: &Receiver<R>, policy: &BatchPolicy) -> Vec<R> {
    let mut batch = Vec::new();
    // Block for the first item.
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return batch,
    }
    let deadline = Instant::now() + policy.window;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn flushes_on_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, window: Duration::from_secs(10) };
        let b = next_batch(&rx, &policy);
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, &policy);
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_on_window() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let policy = BatchPolicy { max_batch: 100, window: Duration::from_millis(20) };
        let b = next_batch(&rx, &policy);
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn empty_on_disconnect() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default());
        assert!(b.is_empty());
    }

    #[test]
    fn late_arrivals_within_window_join() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        let policy = BatchPolicy { max_batch: 10, window: Duration::from_millis(50) };
        let b = next_batch(&rx, &policy);
        handle.join().unwrap();
        assert_eq!(b.len(), 2);
    }
}
