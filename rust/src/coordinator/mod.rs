//! L3 coordination: worker pool, feature-block partitioning, the
//! block-parallel screening executor, the request batcher and the
//! screening service.
//!
//! The vendored crate set has no tokio, so the coordinator is built on
//! std threads: a scoped work-stealing-lite [`pool::parallel_map`] for
//! compute fan-out, a persistent [`pool::ThreadPool`] for connection
//! handling, and blocking channels with deadlines for the batcher.

pub mod batcher;
pub mod blocks;
pub mod parallel;
pub mod pool;
pub mod protocol;
pub mod server;
pub mod shard;

pub use parallel::{screen_all_parallel, screen_all_parallel_with};
pub use pool::{parallel_map, ThreadPool};
pub use shard::{Shard, ShardPlan, ShardedScreener};
