//! Thread-pool substrate (std threads; no tokio/rayon offline).
//!
//! Both entry points capture the submitting thread's span path
//! ([`telemetry::current_path`]) and re-adopt it on the worker
//! ([`telemetry::adopt_path`]), so spans opened inside pooled work nest
//! under their logical parent in exported traces instead of collapsing
//! to depth 0 on an anonymous thread.

use crate::telemetry;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Applies `f` to every item on `workers` scoped threads, preserving
/// order. Work is claimed from a shared atomic counter, so uneven item
/// costs balance automatically (work-stealing-lite).
pub fn parallel_map<I, T, F>(items: &[I], workers: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let parent_path = telemetry::current_path();
    let counter = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        // Shared-by-reference captures for the `move` closures below
        // (only the Copy references move, not the values).
        let counter = &counter;
        let f = &f;
        let parent_path = parent_path.as_str();
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let _attribution = telemetry::adopt_path(parent_path);
                    let mut local = Vec::new();
                    loop {
                        let i = counter.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, t) in h.join().expect("worker panicked") {
                out[i] = Some(t);
            }
        }
    });
    out.into_iter().map(|o| o.expect("missing item")).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent thread pool for connection handling and background jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawns `workers` threads pulling jobs from a shared queue.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    /// Enqueues a job. The submitter's span path travels with it: the
    /// worker adopts it for the job's duration, so spans the job opens
    /// keep their logical nesting in exported traces.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let parent_path = telemetry::current_path();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(move || {
                let _attribution = telemetry::adopt_path(&parent_path);
                f()
            }))
            .expect("pool workers gone");
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A sensible default worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 7, |&i| i * 2);
        assert_eq!(out, items.iter().map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_worker_and_empty() {
        let out = parallel_map(&[1, 2, 3], 1, |&i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<i32> = parallel_map(&[] as &[i32], 4, |&i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn parallel_map_balances_uneven_work() {
        // Items with wildly different costs still complete.
        let items: Vec<u64> = (0..64).map(|i| if i % 13 == 0 { 200_000 } else { 10 }).collect();
        let out = parallel_map(&items, 4, |&spin| {
            let mut acc = 0u64;
            for i in 0..spin {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn thread_pool_runs_jobs_and_joins() {
        let count = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(3);
            assert_eq!(pool.workers(), 3);
            for _ in 0..50 {
                let c = Arc::clone(&count);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins all workers after draining the queue.
        }
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn pooled_work_inherits_span_attribution() {
        use crate::telemetry::{current_path, Span};
        let _outer = Span::enter("test.pool.parent");
        // parallel_map workers adopt the submitter's span path.
        let paths = parallel_map(&[0, 1, 2, 3], 2, |_| current_path());
        for p in &paths {
            assert!(p.starts_with("test.pool.parent"), "got {p:?}");
        }
        // ThreadPool jobs adopt the path captured at execute() time.
        let (tx, rx) = mpsc::channel();
        {
            let pool = ThreadPool::new(2);
            for _ in 0..4 {
                let tx = tx.clone();
                pool.execute(move || {
                    let _ = tx.send(current_path());
                });
            }
            drop(tx);
        }
        let seen: Vec<String> = rx.iter().collect();
        assert_eq!(seen.len(), 4);
        for p in &seen {
            assert!(p.starts_with("test.pool.parent"), "got {p:?}");
        }
    }
}
