//! Block-parallel screening: the multi-worker version of Algorithm 1.
//!
//! The screening pass is embarrassingly parallel across features — the
//! shared context is read-only — so the executor partitions features
//! into nnz-balanced blocks and fans out over [`super::pool::parallel_map`].

use crate::coordinator::blocks;
use crate::coordinator::pool::parallel_map;
use crate::data::cache::FeatureCache;
use crate::data::FeatureMatrix;
use crate::error::Result;
use crate::screening::precompute::{FeatureStats, SharedContext};
use crate::screening::rule::{
    record_screen_telemetry, Rule, RuleKind, ScreenReport, ScreeningRule,
};

/// Minimum `nnz + m` for which multi-threaded screening pays for its
/// thread-spawn cost (measured on this container: a 50k-feature sparse
/// pass runs ~1 ms single-threaded).
pub const PARALLEL_WORK_THRESHOLD: usize = 1_000_000;

/// Parallel counterpart of [`crate::screening::rule::screen_all`].
///
/// `workers = 1` degrades to the sequential path (and is bit-identical
/// to `screen_all` — asserted in tests). Fan-out only engages when the
/// estimated work (`nnz + m`) clears [`PARALLEL_WORK_THRESHOLD`]: below
/// it the whole pass costs well under a millisecond and thread spawning
/// dominates (EXPERIMENTS.md §Perf P5).
pub fn screen_all_parallel<X: FeatureMatrix + Sync>(
    rule: RuleKind,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2: f64,
    workers: usize,
) -> Result<ScreenReport> {
    screen_all_parallel_with(rule, x, y, theta1, lambda1, lambda2, workers, None)
}

/// [`screen_all_parallel`] with an optional [`FeatureCache`]: per-feature
/// stats come from the cache (one θ-dot instead of the four-way panel),
/// the work-threshold check reads the cached total nnz instead of
/// re-deriving it, and the block partitioner reads the cached per-column
/// nnz. Bit-identical to the uncached and sequential paths.
#[allow(clippy::too_many_arguments)]
pub fn screen_all_parallel_with<X: FeatureMatrix + Sync>(
    rule: RuleKind,
    x: &X,
    y: &[f64],
    theta1: &[f64],
    lambda1: f64,
    lambda2: f64,
    workers: usize,
    cache: Option<&FeatureCache>,
) -> Result<ScreenReport> {
    let t0 = std::time::Instant::now();
    let m = x.n_features();
    let mut bounds = vec![f64::INFINITY; m];
    let work = cache.map(|c| c.nnz).unwrap_or_else(|| x.nnz()) + m;
    let workers = if work < PARALLEL_WORK_THRESHOLD { 1 } else { workers.max(1) };
    if rule != RuleKind::None && m > 0 {
        let ctx = SharedContext::build(y, theta1, lambda1, lambda2)?;
        let r = Rule(rule);
        let ranges =
            blocks::balanced_with(x, workers * 4, cache.map(|c| c.col_nnz.as_slice()));
        let results = parallel_map(&ranges, workers, |range| {
            let mut local = Vec::with_capacity(range.len());
            for j in range.clone() {
                let s = match cache {
                    Some(c) => FeatureStats::from_cache(x, c, j, &ctx.ytheta1),
                    None => FeatureStats::compute(x, j, y, &ctx.ytheta1),
                };
                local.push(r.score(&ctx, &s));
            }
            local
        });
        for (range, local) in ranges.iter().zip(results) {
            for (j, score) in range.clone().zip(local) {
                bounds[j] = score;
            }
        }
    }
    let report = ScreenReport::from_bounds(
        rule,
        lambda1,
        lambda2,
        bounds,
        t0.elapsed().as_secs_f64(),
    );
    // Same sweep-amortization semantics as screen_all: one report = one
    // O(nnz) data pass. (Parallel sweeps were previously invisible to
    // the screening.* counters/histograms.)
    record_screen_telemetry(&report, 1, "par");
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::screening::rule::screen_all;
    use crate::svm::problem::Problem;

    #[test]
    fn parallel_matches_sequential_exactly() {
        let p = Problem::from_dataset(&SynthSpec::text(80, 400, 141).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let l1 = p.lambda_max();
        for frac in [0.9, 0.5] {
            let seq = screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, l1, frac * l1)
                .unwrap();
            for workers in [1, 2, 5] {
                let par = screen_all_parallel(
                    RuleKind::Paper,
                    &p.x,
                    &p.y,
                    &theta1,
                    l1,
                    frac * l1,
                    workers,
                )
                .unwrap();
                assert_eq!(par.keep, seq.keep, "workers={workers} frac={frac}");
                // bounds bit-identical (same arithmetic, same order per j)
                assert_eq!(par.bounds, seq.bounds);
            }
        }
    }

    #[test]
    fn none_rule_short_circuits() {
        let p = Problem::from_dataset(&SynthSpec::dense(20, 10, 143).generate());
        let theta1 = p.theta_at_lambda_max().theta();
        let rep = screen_all_parallel(
            RuleKind::None,
            &p.x,
            &p.y,
            &theta1,
            p.lambda_max(),
            0.5 * p.lambda_max(),
            4,
        )
        .unwrap();
        assert_eq!(rep.n_screened(), 0);
    }
}
