//! Feature-block partitioning for the parallel screening executor.

use crate::data::FeatureMatrix;
use std::ops::Range;

/// Fixed-size blocks covering `0..m`.
pub fn fixed(m: usize, block: usize) -> Vec<Range<usize>> {
    assert!(block > 0);
    let mut out = Vec::with_capacity(m.div_ceil(block));
    let mut j = 0;
    while j < m {
        out.push(j..(j + block).min(m));
        j += block;
    }
    out
}

/// nnz-balanced blocks: contiguous ranges whose total non-zeros are
/// approximately equal, so sparse text data with skewed column sizes
/// (Zipf!) doesn't leave workers idle.
pub fn balanced<X: FeatureMatrix>(x: &X, n_blocks: usize) -> Vec<Range<usize>> {
    balanced_with(x, n_blocks, None)
}

/// [`balanced`] with the per-column nnz optionally served from a
/// prebuilt slice (e.g. [`crate::data::cache::FeatureCache::col_nnz`])
/// instead of per-column backend calls.
pub fn balanced_with<X: FeatureMatrix>(
    x: &X,
    n_blocks: usize,
    col_nnz: Option<&[usize]>,
) -> Vec<Range<usize>> {
    let m = x.n_features();
    debug_assert!(col_nnz.is_none_or(|c| c.len() == m));
    match col_nnz {
        Some(c) => balanced_nnz(c, n_blocks),
        None => {
            let counts: Vec<usize> = (0..m).map(|j| x.col_nnz(j)).collect();
            balanced_nnz(&counts, n_blocks)
        }
    }
}

/// The matrix-free core of [`balanced`]: partitions `0..col_nnz.len()`
/// into at most `n_blocks` contiguous ranges of approximately equal
/// total nnz. This is also the shard planner's workhorse
/// ([`crate::coordinator::shard::ShardPlan`]), which balances off the
/// cached per-column nnz without touching the backend.
pub fn balanced_nnz(col_nnz: &[usize], n_blocks: usize) -> Vec<Range<usize>> {
    let m = col_nnz.len();
    let n_blocks = n_blocks.max(1).min(m.max(1));
    if m == 0 {
        return Vec::new();
    }
    // +1 per column so all-zero stretches still split.
    let total: usize = col_nnz.iter().map(|&c| c + 1).sum();
    let target = total.div_ceil(n_blocks);
    let mut out = Vec::with_capacity(n_blocks);
    let mut start = 0;
    let mut acc = 0usize;
    for (j, &c) in col_nnz.iter().enumerate() {
        acc += c + 1;
        if acc >= target && out.len() + 1 < n_blocks {
            out.push(start..j + 1);
            start = j + 1;
            acc = 0;
        }
    }
    if start < m {
        out.push(start..m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn fixed_covers_exactly() {
        let blocks = fixed(10, 3);
        assert_eq!(blocks, vec![0..3, 3..6, 6..9, 9..10]);
        assert_eq!(fixed(0, 4).len(), 0);
    }

    #[test]
    fn balanced_covers_and_balances() {
        let ds = SynthSpec::text(80, 500, 131).generate();
        let blocks = balanced(&ds.x, 8);
        // coverage: contiguous, disjoint, complete
        let mut next = 0;
        for b in &blocks {
            assert_eq!(b.start, next);
            next = b.end;
        }
        assert_eq!(next, 500);
        // balance: max block nnz within 3x of min (Zipf data is rough)
        let nnz: Vec<usize> = blocks
            .iter()
            .map(|b| b.clone().map(|j| ds.x.col_nnz(j)).sum())
            .collect();
        let max = *nnz.iter().max().unwrap();
        let min = *nnz.iter().min().unwrap();
        assert!(max <= 3 * min.max(1) + 200, "imbalance {nnz:?}");
    }

    #[test]
    fn balanced_with_cached_nnz_matches() {
        let ds = SynthSpec::text(60, 300, 135).generate();
        let cache = crate::data::cache::FeatureCache::build(&ds.x, &ds.y);
        assert_eq!(
            balanced(&ds.x, 6),
            balanced_with(&ds.x, 6, Some(&cache.col_nnz))
        );
    }

    #[test]
    fn balanced_nnz_matches_matrix_path() {
        let ds = SynthSpec::text(50, 200, 137).generate();
        let counts: Vec<usize> = (0..200).map(|j| ds.x.col_nnz(j)).collect();
        assert_eq!(balanced(&ds.x, 5), balanced_nnz(&counts, 5));
        assert!(balanced_nnz(&[], 4).is_empty());
        assert_eq!(balanced_nnz(&[0, 0, 0], 3).len(), 3);
    }

    #[test]
    fn balanced_more_blocks_than_features() {
        let ds = SynthSpec::dense(5, 3, 133).generate();
        let blocks = balanced(&ds.x, 10);
        assert!(blocks.len() <= 3);
        assert_eq!(blocks.last().unwrap().end, 3);
    }
}
