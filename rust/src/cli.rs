//! Hand-rolled CLI argument parsing (no clap in the vendored crate set).
//!
//! Grammar: `svmscreen <subcommand> [--flag value | --switch]...`.
//! Flags accumulate into a [`crate::config::RawConfig`] so file config
//! and CLI share one resolution path.

use crate::config::RawConfig;
use crate::error::{Error, Result};

/// A parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    /// The subcommand (first positional).
    pub command: String,
    /// Flags as raw config entries (`--steps 30` → `steps = 30`).
    pub flags: RawConfig,
    /// Bare positionals after the subcommand.
    pub positionals: Vec<String>,
}

/// Flags that take no value (presence ⇒ `true`).
const SWITCHES: &[&str] = &["verbose", "indices", "no-normalize", "csv", "audit", "ledger"];

/// Parses an argument vector (without argv[0]).
pub fn parse_args(args: &[String]) -> Result<Cli> {
    let mut iter = args.iter().peekable();
    let command = iter
        .next()
        .cloned()
        .ok_or_else(|| Error::config("missing subcommand; try `svmscreen help`"))?;
    let mut flags = RawConfig::default();
    let mut positionals = Vec::new();
    while let Some(arg) = iter.next() {
        if let Some(name) = arg.strip_prefix("--") {
            if name.is_empty() {
                return Err(Error::config("bare `--` not supported"));
            }
            if let Some((k, v)) = name.split_once('=') {
                flags.set(k, v);
            } else if SWITCHES.contains(&name) {
                flags.set(name, "true");
            } else {
                let value = iter
                    .next()
                    .ok_or_else(|| Error::config(format!("--{name} needs a value")))?;
                flags.set(name, value.clone());
            }
        } else {
            positionals.push(arg.clone());
        }
    }
    Ok(Cli { command, flags, positionals })
}

/// Usage text for `help` and errors.
pub const USAGE: &str = "\
svmscreen — safe screening for sparse SVM (Zhao & Liu, KDD'14)

USAGE:
  svmscreen <command> [--flag value]...

COMMANDS:
  info      describe a dataset and its lambda_max
            --data synth:text:2000:20000:42 | path.svm
  generate  write a synthetic dataset in libsvm format
            --data synth:<kind>:<n>:<m>:<seed> --out FILE
  solve     solve one lambda
            --data ... --lambda-frac 0.5 [--solver cd|fista] [--tol 1e-6]
            [--trace-out FILE]
  screen    one screening pass (lambda_max -> lambda2)
            --data ... --lambda2-frac 0.5 [--rule paper|ball|sphere|strong]
            [--workers N] [--engine native|pjrt] [--artifacts DIR]
            [--trace-out FILE]
  path      regularization path with sequential screening
            --data ... [--steps 30] [--min-frac 0.05] [--rule ...]
            [--solver ...] [--tol ...] [--workers N] [--csv FILE]
            [--trace-out FILE] [--audit] [--ledger]
  explain   run a path with the provenance ledger armed, then explain
            screening decisions: per-rule near-miss breakdown, top-N
            closest calls, optional single-feature history
            --data ... [--steps ...] [--rule ...] [--feature J] [--top N]
            [--near-miss-eps 1e-2] [--export FILE(.jsonl|.csv)]
  serve     start the screening service
            --data ... [--addr 127.0.0.1:7878] [--workers N] [--shards K]
  help      this text

Config file: --config FILE (key = value lines; CLI flags override).

FLAGS:
  --trace-out FILE  after the run, write the recorded span timeline as a
                    Chrome trace-event JSON file (load in Perfetto or
                    chrome://tracing)
  --audit           safety-audit mode (path): after each step converges,
                    re-check every screened-out feature against the KKT
                    condition; violations are counted in
                    screening.violations and logged as errors
  --ledger          arm the screening provenance ledger for this run:
                    every per-feature verdict (rule, bound, margin) is
                    recorded and summarized after the run
  --feature J       explain: print the full verdict history of feature J
  --top N           explain: print the N closest near-miss verdicts
                    (default 10)
  --near-miss-eps E flag features whose |margin| to the keep/reject
                    threshold is below E (default 1e-2)
  --export FILE     explain: dump every recorded verdict; .csv extension
                    writes CSV, anything else JSONL
  --shards K        serve: partition the feature set into K nnz-balanced
                    shards, each with a long-lived reduced problem and
                    remapped cache reused across batches; kept sets stay
                    bit-identical to unsharded. K <= 1 disables sharding

ENVIRONMENT:
  PALLAS_LOG              stderr log level: error|warn|info|debug|trace|off
                          (default warn); debug traces spans/solves/screens
  PALLAS_LOG_JSON         path to a JSONL event sink (structured telemetry)
  PALLAS_TRACE_CAPACITY   trace ring capacity in records (default 16384;
                          0 disables trace recording)
  PALLAS_TRACE_OUT        like --trace-out, honored by benches and any run
  PALLAS_STATS_DUMP_SECS  serve: emit a full stats snapshot through the
                          sinks every N seconds (fractional ok)
  PALLAS_LEDGER           1/true/yes/on: arm the provenance ledger for any
                          run (equivalent to --ledger, honored by benches)
  PALLAS_LEDGER_CAPACITY  max buffered verdicts before eviction
                          (default 65536)
  PALLAS_NEAR_MISS_EPS    near-miss threshold (default 1e-2)
  PALLAS_SHARDS           default for --shards (serve; <= 1 unsharded)

See docs/OBSERVABILITY.md for the full observability tour.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let cli = parse_args(&v(&[
            "path",
            "--steps",
            "12",
            "--rule=ball",
            "extra",
            "--verbose",
        ]))
        .unwrap();
        assert_eq!(cli.command, "path");
        assert_eq!(cli.flags.get("steps"), Some("12"));
        assert_eq!(cli.flags.get("rule"), Some("ball"));
        assert_eq!(cli.flags.get("verbose"), Some("true"));
        assert_eq!(cli.positionals, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse_args(&v(&["path", "--steps"])).is_err());
        assert!(parse_args(&v(&[])).is_err());
    }
}
