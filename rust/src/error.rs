//! Error taxonomy for the svmscreen crate.
//!
//! Every fallible public API returns [`Result`]. The variants partition
//! failures by subsystem so callers (CLI, server, benches) can react
//! differently to, e.g., a malformed request vs a missing artifact.

use thiserror::Error;

/// Crate-wide error type.
#[derive(Debug, Error)]
pub enum Error {
    /// Input data is malformed (parsing, dimension mismatch, bad labels).
    #[error("data error: {0}")]
    Data(String),

    /// A configuration value is missing or invalid.
    #[error("config error: {0}")]
    Config(String),

    /// Solver failed to make progress or diverged.
    #[error("solver error: {0}")]
    Solver(String),

    /// Screening-rule precondition violated (e.g. lambda2 >= lambda1).
    #[error("screening error: {0}")]
    Screening(String),

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / service failure (pool, protocol, socket).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Construct a [`Error::Data`] from anything displayable.
    pub fn data(msg: impl std::fmt::Display) -> Self {
        Error::Data(msg.to_string())
    }
    /// Construct a [`Error::Config`] from anything displayable.
    pub fn config(msg: impl std::fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }
    /// Construct a [`Error::Solver`] from anything displayable.
    pub fn solver(msg: impl std::fmt::Display) -> Self {
        Error::Solver(msg.to_string())
    }
    /// Construct a [`Error::Screening`] from anything displayable.
    pub fn screening(msg: impl std::fmt::Display) -> Self {
        Error::Screening(msg.to_string())
    }
    /// Construct a [`Error::Runtime`] from anything displayable.
    pub fn runtime(msg: impl std::fmt::Display) -> Self {
        Error::Runtime(msg.to_string())
    }
    /// Construct a [`Error::Coordinator`] from anything displayable.
    pub fn coordinator(msg: impl std::fmt::Display) -> Self {
        Error::Coordinator(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_subsystem() {
        let e = Error::data("bad row 7");
        assert_eq!(e.to_string(), "data error: bad row 7");
        let e = Error::runtime("no artifact");
        assert!(e.to_string().starts_with("runtime error:"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
