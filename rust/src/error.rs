//! Error taxonomy for the svmscreen crate.
//!
//! Every fallible public API returns [`Result`]. The variants partition
//! failures by subsystem so callers (CLI, server, benches) can react
//! differently to, e.g., a malformed request vs a missing artifact.

/// Crate-wide error type (hand-rolled `Display`/`Error` impls — the
/// crate is std-only, so no `thiserror` derive).
#[derive(Debug)]
pub enum Error {
    /// Input data is malformed (parsing, dimension mismatch, bad labels).
    Data(String),

    /// A configuration value is missing or invalid.
    Config(String),

    /// Solver failed to make progress or diverged.
    Solver(String),

    /// Screening-rule precondition violated (e.g. lambda2 >= lambda1).
    Screening(String),

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Coordinator / service failure (pool, protocol, socket).
    Coordinator(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Solver(m) => write!(f, "solver error: {m}"),
            Error::Screening(m) => write!(f, "screening error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Construct a [`Error::Data`] from anything displayable.
    pub fn data(msg: impl std::fmt::Display) -> Self {
        Error::Data(msg.to_string())
    }
    /// Construct a [`Error::Config`] from anything displayable.
    pub fn config(msg: impl std::fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }
    /// Construct a [`Error::Solver`] from anything displayable.
    pub fn solver(msg: impl std::fmt::Display) -> Self {
        Error::Solver(msg.to_string())
    }
    /// Construct a [`Error::Screening`] from anything displayable.
    pub fn screening(msg: impl std::fmt::Display) -> Self {
        Error::Screening(msg.to_string())
    }
    /// Construct a [`Error::Runtime`] from anything displayable.
    pub fn runtime(msg: impl std::fmt::Display) -> Self {
        Error::Runtime(msg.to_string())
    }
    /// Construct a [`Error::Coordinator`] from anything displayable.
    pub fn coordinator(msg: impl std::fmt::Display) -> Self {
        Error::Coordinator(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_subsystem() {
        let e = Error::data("bad row 7");
        assert_eq!(e.to_string(), "data error: bad row 7");
        let e = Error::runtime("no artifact");
        assert!(e.to_string().starts_with("runtime error:"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
