//! F3 (figure): per-λ time breakdown — screening cost vs solve cost,
//! with and without the rule. Paper-shaped expectation: the O(mn) screen
//! is a small fraction of the solve it saves, so `screen+reduced-solve`
//! beats `full-solve` at every step where rejection is nontrivial.

mod common;

use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;

fn main() {
    common::banner("F3", "per-lambda screen/solve time breakdown");
    let bench_t0 = std::time::Instant::now();
    let ds = svmscreen::data::synth::SynthSpec::text(1000, 10000, 9103).generate();
    println!("workload: {}", ds.describe());
    let p = Problem::from_dataset(&ds);
    let grid = geometric(p.lambda_max(), 0.05, 30).unwrap();

    let with = run_path(&p, &grid, &PathConfig { rule: RuleKind::Paper, ..Default::default() })
        .expect("screened path");
    let without = run_path(&p, &grid, &PathConfig { rule: RuleKind::None, ..Default::default() })
        .expect("baseline path");

    let mut t = Table::new(
        "F3: per-step seconds (paper rule vs none)",
        &["lambda/lmax", "screen_s", "solve_s(screened)", "solve_s(full)", "step speedup"],
    );
    let mut csv = Vec::new();
    for k in 0..grid.len() {
        let a = &with.steps[k];
        let b = &without.steps[k];
        let speedup = b.solve_seconds / (a.screen_seconds + a.solve_seconds).max(1e-12);
        t.row(&[
            format!("{:.4}", a.lambda_frac),
            format!("{:.5}", a.screen_seconds),
            format!("{:.5}", a.solve_seconds),
            format!("{:.5}", b.solve_seconds),
            format!("{speedup:.2}x"),
        ]);
        csv.push(vec![
            format!("{:.6}", a.lambda_frac),
            format!("{:.6}", a.screen_seconds),
            format!("{:.6}", a.solve_seconds),
            format!("{:.6}", b.solve_seconds),
        ]);
    }
    println!("{t}");
    let tw = with.totals();
    let to = without.totals();
    println!(
        "totals: screened {:.3}s (screen {:.3}s + solve {:.3}s) vs full {:.3}s -> {:.2}x",
        tw.screen_seconds + tw.solve_seconds,
        tw.screen_seconds,
        tw.solve_seconds,
        to.solve_seconds,
        to.solve_seconds / (tw.screen_seconds + tw.solve_seconds)
    );
    println!(
        "screening overhead: {:.1}% of screened-path time",
        100.0 * tw.screen_seconds / (tw.screen_seconds + tw.solve_seconds)
    );
    common::write_csv(
        "f3_breakdown",
        &["lambda_frac", "screen_s", "solve_screened_s", "solve_full_s"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "f3",
            "text 1000x10000, 30-step path to 0.05 lmax, paper vs none",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(tw.mean_rejection)
        .speedup(to.solve_seconds / (tw.screen_seconds + tw.solve_seconds)),
    );
}
