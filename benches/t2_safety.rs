//! T2 (table): safety audit + bound tightness. Safe rules must report
//! ZERO violations against 1e−9-certified optima; the strong rule is
//! the unsafe comparator. Tightness quantiles show how close the bound
//! tracks the true |θ₂ᵀf̂| (smaller = tighter = more screening power).

mod common;

use svmscreen::data::FeatureMatrix;
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::screening::rule::screen_all;
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    sorted[pos.round() as usize]
}

fn main() {
    common::banner("T2", "safety audit + bound tightness vs certified optima");
    let bench_t0 = std::time::Instant::now();
    let mut paper_checked = 0usize;
    let mut paper_screened = 0usize;
    let mut t = Table::new(
        "T2: screening from lambda1 = 0.8 lmax (solved to 1e-10)",
        &["dataset", "rule", "checked", "screened", "violations", "slack p50", "slack p90"],
    );
    let mut csv = Vec::new();
    let mut safe_violations = 0usize;
    for ds in common::dataset_trio(0.6) {
        let p = Problem::from_dataset(&ds);
        let lambda1 = 0.8 * p.lambda_max();
        let theta1 = common::solved_theta(&p, lambda1);
        for rule in [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong] {
            let mut checked = 0usize;
            let mut screened = 0usize;
            let mut violations = 0usize;
            let mut slacks: Vec<f64> = Vec::new();
            for frac in [0.95, 0.85, 0.7, 0.5, 0.3] {
                let lambda2 = frac * lambda1;
                let exact = solve(
                    SolverKind::Cd,
                    &p.x,
                    &p.y,
                    lambda2,
                    None,
                    &SolveOptions::precise(),
                )
                .expect("precise solve");
                assert!(exact.converged);
                let theta2 = svmscreen::svm::dual::theta_from_primal(
                    &p.x, &p.y, &exact.w, exact.b, lambda2,
                );
                let ytheta2: Vec<f64> =
                    p.y.iter().zip(&theta2).map(|(a, b)| a * b).collect();
                let rep =
                    screen_all(rule, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();
                for j in 0..p.m() {
                    checked += 1;
                    let truth = p.x.col_dot(j, &ytheta2).abs();
                    if rep.bounds[j].is_finite() {
                        // slack = bound − truth ≥ 0 for safe rules
                        slacks.push(rep.bounds[j] - truth);
                    }
                    if !rep.keep[j] {
                        screened += 1;
                        if exact.w[j].abs() > 1e-7 {
                            violations += 1;
                        }
                    }
                }
            }
            if rule.is_safe() {
                safe_violations += violations;
            }
            if rule == RuleKind::Paper {
                paper_checked += checked;
                paper_screened += screened;
            }
            slacks.sort_by(|a, b| a.partial_cmp(b).unwrap());
            t.row(&[
                ds.name.clone(),
                rule.name().into(),
                checked.to_string(),
                screened.to_string(),
                violations.to_string(),
                format!("{:.4}", quantile(&slacks, 0.5)),
                format!("{:.4}", quantile(&slacks, 0.9)),
            ]);
            csv.push(vec![
                ds.name.clone(),
                rule.name().into(),
                checked.to_string(),
                screened.to_string(),
                violations.to_string(),
                format!("{:.6}", quantile(&slacks, 0.5)),
                format!("{:.6}", quantile(&slacks, 0.9)),
            ]);
            // safe-rule bounds must dominate the truth
            if rule.is_safe() {
                let min_slack = slacks.first().copied().unwrap_or(0.0);
                assert!(
                    min_slack > -1e-6,
                    "{} rule {}: bound below truth by {}",
                    ds.name,
                    rule.name(),
                    -min_slack
                );
            }
        }
    }
    println!("{t}");
    assert_eq!(safe_violations, 0, "safe rules must never violate");
    println!("safe-rule violations: {safe_violations} (required: 0) ✔");
    common::write_csv(
        "t2_safety",
        &["dataset", "rule", "checked", "screened", "violations", "slack_p50", "slack_p90"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t2",
            "trio scale=0.6, lambda1=0.8 lmax, 5-frac ladder, all rules vs 1e-10 optima",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(paper_screened as f64 / paper_checked.max(1) as f64)
        .extra(
            "safe_violations",
            svmscreen::coordinator::protocol::Json::Num(safe_violations as f64),
        ),
    );
}
