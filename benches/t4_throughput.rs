//! T4 (table): screening throughput (features/s) across problem sizes
//! and execution engines: native sequential, block-parallel (2/4/8
//! workers), and the AOT/PJRT path. The native path should scale with
//! workers; the PJRT path on this CPU image runs the Pallas kernel in
//! interpret mode (correctness demo — real-TPU estimates live in
//! DESIGN.md §Hardware-Adaptation).

mod common;

use svmscreen::coordinator::screen_all_parallel;
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::report::timer::BenchStats;
use svmscreen::runtime::{screen_all_pjrt, PjrtEngine, PjrtScreenOptions};
use svmscreen::screening::rule::screen_all;

fn main() {
    common::banner("T4", "screening throughput by engine and size");
    let bench_t0 = std::time::Instant::now();
    let mut par8_speedups: Vec<f64> = Vec::new();
    let engine = {
        let dir = PjrtEngine::default_dir();
        if dir.exists() {
            Some(PjrtEngine::load(dir).expect("engine"))
        } else {
            println!("(artifacts missing — PJRT column skipped)");
            None
        }
    };

    let mut t = Table::new(
        "T4: features/second (median of 5)",
        &["n", "m", "nnz", "native", "par x2", "par x4", "par x8", "pjrt(interp)"],
    );
    let mut csv = Vec::new();
    // (n, m, dense?) — the dense rows carry nnz = n*m and are where the
    // block-parallel executor pays; the ultra-sparse text rows finish in
    // well under a millisecond single-threaded, so the executor's
    // work-threshold keeps them sequential (Perf §P5).
    for (n, m, dense) in [
        (250, 2000, false),
        (1000, 10_000, false),
        (1000, 50_000, false),
        (1000, 4_000, true),
        (2000, 10_000, true),
    ] {
        let ds = if dense {
            svmscreen::data::synth::SynthSpec::dense(n, m, 9106).generate()
        } else {
            svmscreen::data::synth::SynthSpec::text(n, m, 9106).generate()
        };
        let p = Problem::from_dataset(&ds);
        let lambda1 = 0.7 * p.lambda_max();
        let theta1 = common::solved_theta(&p, lambda1);
        let lambda2 = 0.6 * lambda1;

        let thru = |secs: f64| m as f64 / secs;
        let native = BenchStats::measure(1, 5, || {
            screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();
        });
        let mut row = vec![
            n.to_string(),
            m.to_string(),
            ds.x.nnz().to_string(),
            format!("{:.0}", thru(native.median())),
        ];
        let mut csv_row = vec![
            n.to_string(),
            m.to_string(),
            format!("{:.1}", thru(native.median())),
        ];
        for workers in [2usize, 4, 8] {
            let par = BenchStats::measure(1, 5, || {
                screen_all_parallel(
                    RuleKind::Paper,
                    &p.x,
                    &p.y,
                    &theta1,
                    lambda1,
                    lambda2,
                    workers,
                )
                .unwrap();
            });
            if workers == 8 {
                par8_speedups.push(native.median() / par.median().max(1e-12));
            }
            row.push(format!("{:.0}", thru(par.median())));
            csv_row.push(format!("{:.1}", thru(par.median())));
        }
        match &engine {
            Some(engine) if n <= 4096 => {
                let pjrt = BenchStats::measure(1, 3, || {
                    screen_all_pjrt(
                        engine,
                        &p.x,
                        &p.y,
                        &theta1,
                        lambda1,
                        lambda2,
                        &PjrtScreenOptions::default(),
                    )
                    .unwrap();
                });
                row.push(format!("{:.0}", thru(pjrt.median())));
                csv_row.push(format!("{:.1}", thru(pjrt.median())));
            }
            _ => {
                row.push("-".into());
                csv_row.push("".into());
            }
        }
        t.row(&row);
        csv.push(csv_row);
    }
    println!("{t}");
    common::write_csv(
        "t4_throughput",
        &["n", "m", "native_fps", "par2_fps", "par4_fps", "par8_fps", "pjrt_fps"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t4",
            "5 problem sizes, paper rule, native vs par x2/4/8 vs pjrt(interp)",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        // headline speedup: parallel x8 over native, averaged over sizes
        .speedup(par8_speedups.iter().sum::<f64>() / par8_speedups.len().max(1) as f64)
        .extra(
            "pjrt_available",
            svmscreen::coordinator::protocol::Json::Bool(engine.is_some()),
        ),
    );
}
