//! T4 (table): screening throughput (features/s) across problem sizes
//! and execution engines: native sequential, block-parallel (2/4/8
//! workers), and the AOT/PJRT path. The native path should scale with
//! workers; the PJRT path on this CPU image runs the Pallas kernel in
//! interpret mode (correctness demo — real-TPU estimates live in
//! DESIGN.md §Hardware-Adaptation).

mod common;

use svmscreen::coordinator::{screen_all_parallel, ShardedScreener};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::report::timer::BenchStats;
use svmscreen::runtime::{screen_all_pjrt, PjrtEngine, PjrtScreenOptions};
use svmscreen::screening::rule::{screen_all, screen_multi_with};

fn main() {
    common::banner("T4", "screening throughput by engine and size");
    let bench_t0 = std::time::Instant::now();
    let mut par8_speedups: Vec<f64> = Vec::new();
    let engine = {
        let dir = PjrtEngine::default_dir();
        if dir.exists() {
            Some(PjrtEngine::load(dir).expect("engine"))
        } else {
            println!("(artifacts missing — PJRT column skipped)");
            None
        }
    };

    let mut t = Table::new(
        "T4: features/second (median of 5)",
        &["n", "m", "nnz", "native", "par x2", "par x4", "par x8", "pjrt(interp)"],
    );
    let mut csv = Vec::new();
    // (n, m, dense?) — the dense rows carry nnz = n*m and are where the
    // block-parallel executor pays; the ultra-sparse text rows finish in
    // well under a millisecond single-threaded, so the executor's
    // work-threshold keeps them sequential (Perf §P5).
    for (n, m, dense) in [
        (250, 2000, false),
        (1000, 10_000, false),
        (1000, 50_000, false),
        (1000, 4_000, true),
        (2000, 10_000, true),
    ] {
        let ds = if dense {
            svmscreen::data::synth::SynthSpec::dense(n, m, 9106).generate()
        } else {
            svmscreen::data::synth::SynthSpec::text(n, m, 9106).generate()
        };
        let p = Problem::from_dataset(&ds);
        let lambda1 = 0.7 * p.lambda_max();
        let theta1 = common::solved_theta(&p, lambda1);
        let lambda2 = 0.6 * lambda1;

        let thru = |secs: f64| m as f64 / secs;
        let native = BenchStats::measure(1, 5, || {
            screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();
        });
        let mut row = vec![
            n.to_string(),
            m.to_string(),
            ds.x.nnz().to_string(),
            format!("{:.0}", thru(native.median())),
        ];
        let mut csv_row = vec![
            n.to_string(),
            m.to_string(),
            format!("{:.1}", thru(native.median())),
        ];
        for workers in [2usize, 4, 8] {
            let par = BenchStats::measure(1, 5, || {
                screen_all_parallel(
                    RuleKind::Paper,
                    &p.x,
                    &p.y,
                    &theta1,
                    lambda1,
                    lambda2,
                    workers,
                )
                .unwrap();
            });
            if workers == 8 {
                par8_speedups.push(native.median() / par.median().max(1e-12));
            }
            row.push(format!("{:.0}", thru(par.median())));
            csv_row.push(format!("{:.1}", thru(par.median())));
        }
        match &engine {
            Some(engine) if n <= 4096 => {
                let pjrt = BenchStats::measure(1, 3, || {
                    screen_all_pjrt(
                        engine,
                        &p.x,
                        &p.y,
                        &theta1,
                        lambda1,
                        lambda2,
                        &PjrtScreenOptions::default(),
                    )
                    .unwrap();
                });
                row.push(format!("{:.0}", thru(pjrt.median())));
                csv_row.push(format!("{:.1}", thru(pjrt.median())));
            }
            _ => {
                row.push("-".into());
                csv_row.push("".into());
            }
        }
        t.row(&row);
        csv.push(csv_row);
    }
    println!("{t}");
    common::write_csv(
        "t4_throughput",
        &["n", "m", "native_fps", "par2_fps", "par4_fps", "par8_fps", "pjrt_fps"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t4",
            "5 problem sizes, paper rule, native vs par x2/4/8 vs pjrt(interp)",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        // headline speedup: parallel x8 over native, averaged over sizes
        .speedup(par8_speedups.iter().sum::<f64>() / par8_speedups.len().max(1) as f64)
        .extra(
            "pjrt_available",
            svmscreen::coordinator::protocol::Json::Bool(engine.is_some()),
        ),
    );

    shard_section();
}

/// T4-shard: the server batch path, sharded (`--shards 4`) vs unsharded,
/// on the largest text problem above. Both sides screen one batch of 8
/// λ₂ targets against the same cached stats; the kept sets are
/// bit-identical (asserted), so the artifact isolates the fan-out cost
/// vs the per-shard cache-locality win. Emits `BENCH_t4_shard.json` for
/// the regress gate and the CI step summary.
fn shard_section() {
    const SHARDS: usize = 4;
    common::banner("T4-shard", "batch screening: 4-way sharded vs unsharded");
    let t0 = std::time::Instant::now();
    let ds = svmscreen::data::synth::SynthSpec::text(1000, 50_000, 9106).generate();
    let p = Problem::from_dataset(&ds);
    let lambda1 = 0.7 * p.lambda_max();
    let theta1 = common::solved_theta(&p, lambda1);
    let lambda2s: Vec<f64> = (1..=8).map(|k| (0.9 - 0.05 * k as f64) * lambda1).collect();
    let m = p.m();
    // Warm the path-wide cache outside the timed region (both sides
    // reuse it; the unsharded sweep reads it directly, the shards hold
    // remapped copies built here).
    let _ = p.cache();
    let sc = ShardedScreener::build(&p, SHARDS, SHARDS).expect("shard build");

    let flat = BenchStats::measure(1, 5, || {
        screen_multi_with(
            RuleKind::Paper,
            &p.x,
            &p.y,
            &theta1,
            lambda1,
            &lambda2s,
            Some(p.cache()),
        )
        .unwrap();
    });
    let sharded = BenchStats::measure(1, 5, || {
        sc.screen_multi(RuleKind::Paper, &p.y, &theta1, lambda1, &lambda2s).unwrap();
    });
    // Bit-identity spot check — a bench must not certify a wrong result.
    let a = screen_multi_with(
        RuleKind::Paper,
        &p.x,
        &p.y,
        &theta1,
        lambda1,
        &lambda2s,
        Some(p.cache()),
    )
    .unwrap();
    let b = sc.screen_multi(RuleKind::Paper, &p.y, &theta1, lambda1, &lambda2s).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.keep, y.keep, "sharded kept set diverged");
    }

    let fps = |secs: f64| (m * lambda2s.len()) as f64 / secs;
    let unsharded_fps = fps(flat.median());
    let sharded_fps = fps(sharded.median());
    println!(
        "unsharded: {unsharded_fps:.0} features/s   sharded x{SHARDS}: {sharded_fps:.0} features/s   ({:.2}x)",
        sharded_fps / unsharded_fps.max(1e-12)
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t4_shard",
            "batch of 8 lambda2 targets on text 1000x50k, 4 shards vs unsharded",
        )
        .wall_seconds(sharded.median())
        .speedup(flat.median() / sharded.median().max(1e-12))
        .extra(
            "unsharded_fps",
            svmscreen::coordinator::protocol::Json::Num(unsharded_fps),
        )
        .extra(
            "sharded_fps",
            svmscreen::coordinator::protocol::Json::Num(sharded_fps),
        )
        .extra(
            "shards",
            svmscreen::coordinator::protocol::Json::Num(SHARDS as f64),
        ),
    );
    println!("[t4_shard] section wall {:.2}s", t0.elapsed().as_secs_f64());
}
