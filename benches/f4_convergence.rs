//! F4 (figure): solver convergence (certified duality gap vs epoch) on
//! the full problem vs the screened problem at a fixed λ. Screening
//! shrinks the sweep, so the screened curve reaches any gap level in
//! less wall-clock (and typically fewer epochs, since the inactive
//! coordinates no longer pollute the active-set heuristic).

mod common;

use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::screening::rule::screen_all;
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};
use svmscreen::solver::reduced::ReducedProblem;

fn main() {
    common::banner("F4", "duality-gap convergence: full vs screened problem");
    let bench_t0 = std::time::Instant::now();
    let ds = svmscreen::data::synth::SynthSpec::dense(400, 800, 9104).generate();
    println!("workload: {}", ds.describe());
    let p = Problem::from_dataset(&ds);
    let lambda1 = 0.35 * p.lambda_max();
    let lambda2 = 0.30 * p.lambda_max();
    let theta1 = common::solved_theta(&p, lambda1);
    let screen = screen_all(RuleKind::Paper, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();
    println!(
        "screened {} / {} features for lambda2 = 0.30 lmax",
        screen.n_screened(),
        p.m()
    );

    let opts = SolveOptions {
        tol: 1e-10,
        max_iter: 3000,
        gap_check_every: 2,
        record_gap_trace: true,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let full = solve(SolverKind::Cd, &p.x, &p.y, lambda2, None, &opts).unwrap();
    let full_time = t0.elapsed().as_secs_f64();
    let red = ReducedProblem::build(&p.x, screen.kept_indices()).unwrap();
    let t0 = std::time::Instant::now();
    let scr = red.solve(SolverKind::Cd, &p.y, lambda2, None, &opts).unwrap();
    let scr_time = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "F4: rel duality gap by epoch",
        &["epoch", "full problem", "screened problem"],
    );
    let mut csv = Vec::new();
    let max_len = full.gap_trace.len().max(scr.gap_trace.len());
    for i in 0..max_len {
        let f = full.gap_trace.get(i);
        let s = scr.gap_trace.get(i);
        t.row(&[
            f.or(s).map(|v| v.0.to_string()).unwrap_or_default(),
            f.map(|v| format!("{:.3e}", v.1)).unwrap_or_else(|| "-".into()),
            s.map(|v| format!("{:.3e}", v.1)).unwrap_or_else(|| "-".into()),
        ]);
        csv.push(vec![
            f.or(s).map(|v| v.0.to_string()).unwrap_or_default(),
            f.map(|v| format!("{:.6e}", v.1)).unwrap_or_default(),
            s.map(|v| format!("{:.6e}", v.1)).unwrap_or_default(),
        ]);
    }
    println!("{t}");
    println!(
        "time to gap<=1e-10: full {:.3}s ({} epochs) vs screened {:.3}s ({} epochs)",
        full_time, full.iterations, scr_time, scr.iterations
    );
    assert!(scr_time <= full_time, "screened solve should be faster");
    common::write_csv("f4_convergence", &["epoch", "full", "screened"], &csv);
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "f4",
            "dense 400x800, lambda2=0.30 lmax, cd to gap 1e-10",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(screen.rejection_ratio())
        .speedup(full_time / scr_time.max(1e-12)),
    );
}
