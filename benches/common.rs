//! Shared helpers for the experiment benches (no criterion in the
//! vendored crate set — each bench is a `harness = false` binary built on
//! `svmscreen::report::timer::BenchStats`).
#![allow(dead_code)]

use svmscreen::data::dataset::Dataset;
use svmscreen::data::synth::SynthSpec;
use svmscreen::prelude::*;
use svmscreen::solver::api::{solve, SolveOptions, SolverKind};

/// The three dataset regimes every experiment sweeps (DESIGN.md §4).
pub fn dataset_trio(scale: f64) -> Vec<Dataset> {
    let s = |v: usize| ((v as f64 * scale) as usize).max(20);
    vec![
        SynthSpec::dense(s(300), s(600), 9001).generate(),
        SynthSpec::text(s(500), s(3000), 9002).generate(),
        SynthSpec::corr(s(300), s(600), 9003).generate(),
    ]
}

/// Solves at `lambda1` to high precision and returns the Eq. 20 dual map.
pub fn solved_theta(p: &Problem, lambda1: f64) -> Vec<f64> {
    let rep = solve(
        SolverKind::Cd,
        &p.x,
        &p.y,
        lambda1,
        None,
        &SolveOptions { tol: 1e-10, max_iter: 50_000, ..Default::default() },
    )
    .expect("solve");
    assert!(rep.converged, "lambda1 solve did not converge: {:?}", rep.gap);
    svmscreen::svm::dual::theta_from_primal(&p.x, &p.y, &rep.w, rep.b, lambda1)
}

/// Writes a CSV under `target/experiments/` and reports the path.
pub fn write_csv(name: &str, headers: &[&str], rows: &[Vec<String>]) {
    let path = format!("target/experiments/{name}.csv");
    svmscreen::report::csv::write_file(&path, headers, rows).expect("csv write");
    println!("[csv] {path}");
}

/// Marks the start of a bench in the log.
pub fn banner(id: &str, what: &str) {
    println!("\n================================================================");
    println!("{id}: {what}");
    println!("================================================================");
}

/// Emits the standardized `BENCH_<id>.json` artifact (schema
/// `pallas.bench.v1`). A write failure is reported but never fails the
/// bench — the human-readable tables above are the primary output.
pub fn emit_artifact(art: svmscreen::report::bench::BenchArtifact) {
    if let Err(e) = art.write() {
        eprintln!("[bench] artifact not written: {e}");
    }
}
