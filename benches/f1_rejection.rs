//! F1 (figure): rejection ratio vs λ/λ_max along the path, per dataset
//! and rule. Paper-shaped expectation: all safe rules → 1 as λ→λ_max;
//! paper ≥ ball ≥ sphere everywhere; power decays as λ shrinks.

mod common;

use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;

fn main() {
    common::banner("F1", "rejection ratio along the regularization path");
    // Arm the provenance ledger: CI exports the near-miss verdicts as
    // an artifact (f1_ledger.jsonl) and summarizes them per rule.
    let ledger = svmscreen::diag::ledger::global();
    ledger.set_enabled(true);
    let bench_t0 = std::time::Instant::now();
    let mut csv: Vec<Vec<String>> = Vec::new();
    let mut paper_rej: Vec<f64> = Vec::new();
    for ds in common::dataset_trio(1.0) {
        let p = Problem::from_dataset(&ds);
        let grid = geometric(p.lambda_max(), 0.05, 30).unwrap();
        let mut series: Vec<(RuleKind, Vec<f64>)> = Vec::new();
        for rule in [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere] {
            let rep = run_path(&p, &grid, &PathConfig { rule, ..Default::default() })
                .expect("path");
            if rule == RuleKind::Paper {
                paper_rej.push(rep.totals().mean_rejection);
            }
            series.push((rule, rep.steps.iter().map(|s| s.rejection).collect()));
        }
        let mut t = Table::new(
            format!("F1 {} (n={} m={})", ds.name, ds.n(), ds.m()),
            &["lambda/lmax", "paper", "ball", "sphere"],
        );
        for (k, &lam) in grid.iter().enumerate() {
            let frac = lam / p.lambda_max();
            t.row(&[
                format!("{frac:.4}"),
                format!("{:.3}", series[0].1[k]),
                format!("{:.3}", series[1].1[k]),
                format!("{:.3}", series[2].1[k]),
            ]);
            csv.push(vec![
                ds.name.clone(),
                format!("{frac:.6}"),
                format!("{:.6}", series[0].1[k]),
                format!("{:.6}", series[1].1[k]),
                format!("{:.6}", series[2].1[k]),
            ]);
        }
        println!("{t}");
        // shape assertions (who wins)
        for k in 0..grid.len() {
            assert!(series[0].1[k] >= series[1].1[k] - 1e-9, "paper < ball at {k}");
            assert!(series[1].1[k] >= series[2].1[k] - 1e-9, "ball < sphere at {k}");
        }
    }
    common::write_csv(
        "f1_rejection",
        &["dataset", "lambda_frac", "paper", "ball", "sphere"],
        &csv,
    );
    // Ledger export + per-rule near-miss counts for the CI step summary.
    let summary = ledger.summary();
    println!(
        "[ledger] {} verdict(s) recorded, {} near-miss(es) (eps {:.1e})",
        summary.recorded, summary.near_misses, summary.near_miss_eps
    );
    let near_misses = ledger.near_misses();
    match svmscreen::report::diag::write_jsonl("f1_ledger.jsonl", &near_misses) {
        Ok(()) => println!("[ledger] f1_ledger.jsonl ({} near-miss verdicts)", near_misses.len()),
        Err(e) => eprintln!("[ledger] export not written: {e}"),
    }
    let counters = svmscreen::telemetry::global().snapshot().counters;
    let near = |rule: &str| {
        *counters.get(&format!("screening.{rule}.near_miss")).unwrap_or(&0) as f64
    };
    use svmscreen::coordinator::protocol::Json;
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "f1",
            "trio scale=1.0, 30-step path to 0.05 lmax, rules=paper/ball/sphere",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(paper_rej.iter().sum::<f64>() / paper_rej.len().max(1) as f64)
        .extra("csv_rows", Json::Num(csv.len() as f64))
        .extra("near_miss_paper", Json::Num(near("paper")))
        .extra("near_miss_ball", Json::Num(near("ball")))
        .extra("near_miss_sphere", Json::Num(near("sphere")))
        .extra("ledger_dropped", Json::Num(summary.dropped as f64)),
    );
}
