//! T1 (table): end-to-end path-training time per rule and solver, with
//! the speedup column. Paper-shaped expectation: every safe rule
//! preserves the solution path; the paper rule gives the largest
//! speedup; the unsafe strong rule is comparable but needs its repair
//! loop.

mod common;

use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::solver::api::SolverKind;

fn main() {
    common::banner("T1", "end-to-end path speedup per rule and solver");
    let bench_t0 = std::time::Instant::now();
    let mut paper_speedups: Vec<f64> = Vec::new();
    let mut paper_rejections: Vec<f64> = Vec::new();
    let mut t = Table::new(
        "T1: 30-step path to 0.05 lmax",
        &["dataset", "solver", "rule", "total_s", "screen_s", "mean_rej%", "violations", "speedup"],
    );
    let mut csv = Vec::new();
    for ds in common::dataset_trio(1.0) {
        let p = Problem::from_dataset(&ds);
        let grid = geometric(p.lambda_max(), 0.05, 30).unwrap();
        // FISTA only on the (small) dense set — it is the slow comparator
        // that demonstrates solver-independence, not the workhorse.
        let solvers: Vec<SolverKind> = if ds.name.contains("dense") {
            vec![SolverKind::Cd, SolverKind::Fista]
        } else {
            vec![SolverKind::Cd]
        };
        for solver in solvers {
            let mut baseline = None;
            for rule in
                [RuleKind::None, RuleKind::Sphere, RuleKind::BallEq, RuleKind::Paper, RuleKind::Strong]
            {
                let cfg = PathConfig { rule, solver, ..Default::default() };
                let rep = run_path(&p, &grid, &cfg).expect("path");
                let totals = rep.totals();
                let total = rep.total_seconds;
                if rule == RuleKind::None {
                    baseline = Some(total);
                }
                let speedup = baseline.unwrap() / total;
                if rule == RuleKind::Paper {
                    paper_speedups.push(speedup);
                    paper_rejections.push(totals.mean_rejection);
                }
                t.row(&[
                    ds.name.clone(),
                    solver.name().into(),
                    rule.name().into(),
                    format!("{total:.3}"),
                    format!("{:.3}", totals.screen_seconds),
                    format!("{:.1}", 100.0 * totals.mean_rejection),
                    totals.violations.to_string(),
                    format!("{speedup:.2}x"),
                ]);
                csv.push(vec![
                    ds.name.clone(),
                    solver.name().into(),
                    rule.name().into(),
                    format!("{total:.6}"),
                    format!("{:.6}", totals.screen_seconds),
                    format!("{:.6}", totals.mean_rejection),
                    totals.violations.to_string(),
                    format!("{speedup:.4}"),
                ]);
            }
        }
    }
    println!("{t}");
    common::write_csv(
        "t1_speedup",
        &["dataset", "solver", "rule", "total_s", "screen_s", "mean_rejection", "violations", "speedup"],
        &csv,
    );
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t1",
            "trio scale=1.0, 30-step path to 0.05 lmax, all rules x cd/fista",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(mean(&paper_rejections))
        .speedup(mean(&paper_speedups))
        .extra(
            "runs",
            svmscreen::coordinator::protocol::Json::Num(csv.len() as f64),
        ),
    );
}
