//! F5 (figure, supplementary): path anatomy — kept-set size vs true
//! active-set size vs λ, plus the bound distribution at a mid-path step.
//! Shows how much head-room the rule leaves (kept − nnz = features the
//! bound could not certify inactive).

mod common;

use svmscreen::path::grid::geometric;
use svmscreen::path::runner::{run_path, PathConfig};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::screening::rule::screen_all;

fn main() {
    common::banner("F5", "path anatomy: kept vs active vs screened");
    let bench_t0 = std::time::Instant::now();
    let ds = svmscreen::data::synth::SynthSpec::text(600, 5000, 9108).generate();
    println!("workload: {}", ds.describe());
    let p = Problem::from_dataset(&ds);
    let grid = geometric(p.lambda_max(), 0.05, 25).unwrap();
    let rep = run_path(&p, &grid, &PathConfig::default()).expect("path");

    let mut t = Table::new(
        "F5: per-step anatomy (paper rule)",
        &["lambda/lmax", "screened", "kept", "nnz", "kept/nnz"],
    );
    let mut csv = Vec::new();
    for s in &rep.steps {
        t.row(&[
            format!("{:.4}", s.lambda_frac),
            s.screened.to_string(),
            s.kept.to_string(),
            s.nnz.to_string(),
            format!("{:.1}", s.kept as f64 / s.nnz.max(1) as f64),
        ]);
        csv.push(vec![
            format!("{:.6}", s.lambda_frac),
            s.screened.to_string(),
            s.kept.to_string(),
            s.nnz.to_string(),
        ]);
    }
    println!("{t}");

    // Bound histogram at a mid-path step.
    let k = grid.len() / 2;
    let theta = svmscreen::svm::dual::theta_from_primal(
        &p.x,
        &p.y,
        &rep.weights[k - 1],
        rep.biases[k - 1],
        grid[k - 1],
    );
    let sr = screen_all(RuleKind::Paper, &p.x, &p.y, &theta, grid[k - 1], grid[k]).unwrap();
    let mut hist = [0usize; 8];
    for &b in &sr.bounds {
        let bin = ((b / 0.25) as usize).min(7);
        hist[bin] += 1;
    }
    let mut ht = Table::new(
        format!("bound histogram at lambda/lmax = {:.3}", grid[k] / p.lambda_max()),
        &["bound range", "features"],
    );
    for (i, c) in hist.iter().enumerate() {
        let label = if i == 7 {
            ">= 1.75".to_string()
        } else {
            format!("[{:.2}, {:.2})", 0.25 * i as f64, 0.25 * (i + 1) as f64)
        };
        ht.row(&[label, c.to_string()]);
    }
    println!("{ht}");
    common::write_csv(
        "f5_path_profile",
        &["lambda_frac", "screened", "kept", "nnz"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "f5",
            "text 600x5000, 25-step path to 0.05 lmax, paper rule",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(rep.totals().mean_rejection)
        .extra(
            "steps",
            svmscreen::coordinator::protocol::Json::Num(rep.steps.len() as f64),
        ),
    );
}
