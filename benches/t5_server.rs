//! T5 (table): the screening service under load — request latency,
//! throughput and effective batch size as a function of the batching
//! window and client concurrency. The batcher amortizes the O(nnz)
//! stats sweep across same-θ₁ requests, so throughput should rise with
//! both knobs while latency stays bounded by the window.

mod common;

use std::time::{Duration, Instant};
use svmscreen::coordinator::batcher::BatchPolicy;
use svmscreen::coordinator::protocol::Json;
use svmscreen::coordinator::server::{Client, ScreeningServer, ServerConfig};
use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::report::timer::BenchStats;

fn main() {
    common::banner("T5", "screening service: batching vs latency/throughput");
    let bench_t0 = std::time::Instant::now();
    let mut best_rps = 0.0f64;
    let mut total_reqs = 0u64;
    let ds = svmscreen::data::synth::SynthSpec::text(500, 5000, 9107).generate();
    println!("workload: {}", ds.describe());

    let mut t = Table::new(
        "T5: 40 requests/client, lambda ladder below 0.7 lmax",
        &["window_ms", "clients", "reqs", "batches", "mean_batch", "p50 lat", "p90 lat", "req/s"],
    );
    let mut csv = Vec::new();
    for window_ms in [0u64, 2, 8] {
        for clients in [1usize, 4, 8] {
            let p = Problem::from_dataset(&ds);
            let lmax = p.lambda_max();
            let server = ScreeningServer::start(
                p,
                ServerConfig {
                    workers: 8,
                    batch: BatchPolicy {
                        max_batch: 32,
                        window: Duration::from_millis(window_ms),
                    },
                    ..Default::default()
                },
            )
            .expect("server");
            let addr = server.addr;
            // Move the server's dual point inward once.
            {
                let mut c = Client::connect(addr).unwrap();
                let r = c
                    .request(&Json::obj(vec![
                        ("cmd", Json::Str("solve".into())),
                        ("lambda", Json::Num(0.7 * lmax)),
                    ]))
                    .unwrap();
                assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
            }
            let t0 = Instant::now();
            let handles: Vec<_> = (0..clients)
                .map(|k| {
                    std::thread::spawn(move || {
                        let mut c = Client::connect(addr).unwrap();
                        let mut lat = Vec::new();
                        for s in 0..40 {
                            let frac = 0.95 - 0.015 * (s % 30) as f64 - 0.002 * k as f64;
                            let t = Instant::now();
                            let rep = c
                                .request(&Json::obj(vec![
                                    ("cmd", Json::Str("screen".into())),
                                    ("lambda2", Json::Num(frac * 0.7 * lmax)),
                                ]))
                                .unwrap();
                            assert_eq!(
                                rep.get("ok"),
                                Some(&Json::Bool(true)),
                                "{rep:?}"
                            );
                            lat.push(t.elapsed().as_secs_f64());
                        }
                        lat
                    })
                })
                .collect();
            let mut lats = Vec::new();
            for h in handles {
                lats.extend(h.join().unwrap());
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = BenchStats::from_samples(lats);
            let (screens, batches, _) = server.metrics();
            let mean_batch = screens as f64 / batches.max(1) as f64;
            best_rps = best_rps.max(screens as f64 / wall);
            total_reqs += screens;
            t.row(&[
                window_ms.to_string(),
                clients.to_string(),
                screens.to_string(),
                batches.to_string(),
                format!("{mean_batch:.2}"),
                svmscreen::report::timer::fmt_duration(stats.median()),
                svmscreen::report::timer::fmt_duration(stats.p90()),
                format!("{:.0}", screens as f64 / wall),
            ]);
            csv.push(vec![
                window_ms.to_string(),
                clients.to_string(),
                format!("{mean_batch:.4}"),
                format!("{:.6}", stats.median()),
                format!("{:.6}", stats.p90()),
                format!("{:.2}", screens as f64 / wall),
            ]);
            server.shutdown();
        }
    }
    println!("{t}");
    common::write_csv(
        "t5_server",
        &["window_ms", "clients", "mean_batch", "lat_p50_s", "lat_p90_s", "req_per_s"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t5",
            "text 500x5000, window 0/2/8ms x clients 1/4/8, 40 reqs/client",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .extra(
            "best_req_per_s",
            svmscreen::coordinator::protocol::Json::Num(best_rps),
        )
        .extra(
            "total_requests",
            svmscreen::coordinator::protocol::Json::Num(total_reqs as f64),
        ),
    );
}
