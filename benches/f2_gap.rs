//! F2 (figure): screening power vs the λ₁→λ₂ gap. The convex set K
//! shrinks as λ₂→λ₁ (the ball radius is ½‖1/λ₂ − θ₁‖), so rejection
//! should rise monotonically toward the small-gap end — the geometric
//! heart of the sequential rule.

mod common;

use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::screening::rule::screen_all;

fn main() {
    common::banner("F2", "screening power vs lambda1/lambda2 gap");
    let bench_t0 = std::time::Instant::now();
    let ds = svmscreen::data::synth::SynthSpec::text(500, 3000, 9102).generate();
    let p = Problem::from_dataset(&ds);
    let lambda1 = 0.7 * p.lambda_max();
    let theta1 = common::solved_theta(&p, lambda1);

    let mut t = Table::new(
        format!("F2 {} (lambda1 = 0.7 lmax)", ds.name),
        &["lambda2/lambda1", "paper", "ball", "sphere", "strong(unsafe)"],
    );
    let mut csv = Vec::new();
    let mut prev_paper = 1.0f64;
    let mut paper_sum = 0.0f64;
    let mut paper_n = 0usize;
    for pct in [99, 97, 95, 90, 85, 80, 70, 60, 50, 40, 30] {
        let frac = pct as f64 / 100.0;
        let lambda2 = frac * lambda1;
        let mut cells = vec![format!("{frac:.2}")];
        let mut row = vec![format!("{frac:.4}")];
        let mut paper_rej = 0.0;
        for rule in [RuleKind::Paper, RuleKind::BallEq, RuleKind::Sphere, RuleKind::Strong] {
            let rep = screen_all(rule, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();
            if rule == RuleKind::Paper {
                paper_rej = rep.rejection_ratio();
                paper_sum += paper_rej;
                paper_n += 1;
            }
            cells.push(format!("{:.3}", rep.rejection_ratio()));
            row.push(format!("{:.6}", rep.rejection_ratio()));
        }
        t.row(&cells);
        csv.push(row);
        // monotone in the gap
        assert!(
            paper_rej <= prev_paper + 1e-9,
            "rejection should shrink as the gap widens"
        );
        prev_paper = paper_rej;
    }
    println!("{t}");
    common::write_csv(
        "f2_gap",
        &["lambda2_over_lambda1", "paper", "ball", "sphere", "strong"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "f2",
            "text 500x3000, lambda1=0.7 lmax, gap sweep 0.99..0.30, all rules",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(paper_sum / paper_n.max(1) as f64),
    );
}
