//! T3 (table): ablation of the convex set K — what each ingredient of
//! the paper's construction buys:
//!
//! * sphere (Cauchy–Schwarz only) → + equality `θᵀy = 0` (ball) →
//!   + variational-inequality half-space (paper);
//! * the KKT case mix (how often the half-space actually binds,
//!   Thm 6.5 / 6.7 / 6.9), per λ-gap.

mod common;

use svmscreen::prelude::*;
use svmscreen::report::table::Table;
use svmscreen::screening::paper::{bound_cased, BoundCase};
use svmscreen::screening::precompute::{FeatureStats, SharedContext};
use svmscreen::screening::rule::screen_all;

fn main() {
    common::banner("T3", "ablation of K + KKT case mix");
    let bench_t0 = std::time::Instant::now();
    let mut paper_rej: Vec<f64> = Vec::new();
    let ds = svmscreen::data::synth::SynthSpec::text(500, 3000, 9105).generate();
    println!("workload: {}", ds.describe());
    let p = Problem::from_dataset(&ds);

    let mut t = Table::new(
        "T3: rejection by rule + case mix (lambda2 = 0.9 lambda1)",
        &[
            "lambda1/lmax",
            "sphere",
            "ball(+eq)",
            "paper(+halfspace)",
            "colinear%",
            "ball-case%",
            "plane-case%",
            "degen%",
            "halfspace-improved%",
        ],
    );
    let mut csv = Vec::new();
    for l1_frac in [0.9, 0.7, 0.5, 0.3] {
        let lambda1 = l1_frac * p.lambda_max();
        let theta1 = common::solved_theta(&p, lambda1);
        let lambda2 = 0.9 * lambda1;

        let mut rej = Vec::new();
        for rule in [RuleKind::Sphere, RuleKind::BallEq, RuleKind::Paper] {
            let rep = screen_all(rule, &p.x, &p.y, &theta1, lambda1, lambda2).unwrap();
            rej.push(rep.rejection_ratio());
        }

        // Case mix + per-feature half-space improvement.
        let ctx = SharedContext::build(&p.y, &theta1, lambda1, lambda2).unwrap();
        let mut counts = [0usize; 4];
        let mut improved = 0usize;
        for j in 0..p.m() {
            let s = FeatureStats::compute(&p.x, j, &p.y, &ctx.ytheta1);
            let (u, c1, c2) = bound_cased(&ctx, &s);
            for c in [c1, c2] {
                counts[match c {
                    BoundCase::Colinear => 0,
                    BoundCase::Ball => 1,
                    BoundCase::Plane => 2,
                    BoundCase::Degenerate => 3,
                }] += 1;
            }
            let ball = svmscreen::screening::variants::ball_eq_bound(&ctx, &s);
            if u < ball - 1e-9 {
                improved += 1;
            }
        }
        let total = (2 * p.m()) as f64;
        t.row(&[
            format!("{l1_frac:.2}"),
            format!("{:.3}", rej[0]),
            format!("{:.3}", rej[1]),
            format!("{:.3}", rej[2]),
            format!("{:.1}", 100.0 * counts[0] as f64 / total),
            format!("{:.1}", 100.0 * counts[1] as f64 / total),
            format!("{:.1}", 100.0 * counts[2] as f64 / total),
            format!("{:.1}", 100.0 * counts[3] as f64 / total),
            format!("{:.1}", 100.0 * improved as f64 / p.m() as f64),
        ]);
        csv.push(vec![
            format!("{l1_frac:.4}"),
            format!("{:.6}", rej[0]),
            format!("{:.6}", rej[1]),
            format!("{:.6}", rej[2]),
            format!("{:.6}", counts[2] as f64 / total),
            format!("{:.6}", improved as f64 / p.m() as f64),
        ]);
        assert!(rej[2] >= rej[1] - 1e-9 && rej[1] >= rej[0] - 1e-9, "ordering");
        paper_rej.push(rej[2]);
    }
    println!("{t}");
    println!(
        "note: the half-space binds for the minority of features whose \
         direction falls in the cut cap; its improvement is real but \
         secondary to the ball shrinking (see EXPERIMENTS.md §T3)."
    );
    common::write_csv(
        "t3_ablation",
        &["lambda1_frac", "sphere", "ball", "paper", "plane_case_frac", "improved_frac"],
        &csv,
    );
    common::emit_artifact(
        svmscreen::report::bench::BenchArtifact::new(
            "t3",
            "text 500x3000, lambda2=0.9 lambda1, sphere/ball/paper ablation",
        )
        .wall_seconds(bench_t0.elapsed().as_secs_f64())
        .mean_rejection(paper_rej.iter().sum::<f64>() / paper_rej.len().max(1) as f64),
    );
}
