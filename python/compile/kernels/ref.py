"""Pure-jnp oracle for the L1 kernels — the build-time correctness signal.

``screen_bounds_ref`` recomputes the screening bound with plain jnp ops in
float64 (when x64 is enabled by the caller), structured as directly as
possible from the paper's formulas so a divergence between kernel and
oracle localizes to the kernel's fusion/tiling, not the math.
"""

from __future__ import annotations

import jax.numpy as jnp

_COS_EPS = 1e-9
_ZERO_EPS = 1e-14
_TINY = 1e-30


def shared_scalars(y, theta1, lambda1, lambda2):
    """Feature-independent scalars as a dict (float64-friendly)."""
    y = jnp.asarray(y)
    theta1 = jnp.asarray(theta1)
    n = y.shape[0]
    inv1 = 1.0 / lambda1
    inv2 = 1.0 / lambda2
    a_raw = theta1 - inv1
    b = 0.5 * (inv2 - theta1)
    ysq = jnp.sum(y * y)
    na = jnp.sqrt(jnp.sum(a_raw * a_raw))
    has_a = bool(na > 1e-12 * (1.0 + inv1 * float(n) ** 0.5))
    a = a_raw / na if has_a else jnp.zeros_like(a_raw)
    out = dict(
        inv1=inv1,
        inv2=inv2,
        n=float(n),
        ysq=ysq,
        na=na,
        has_a=has_a,
        a_y=jnp.sum(a * y),
        a_1=jnp.sum(a),
        a_t=jnp.sum(a * theta1),
        a_b=jnp.sum(a * b),
        b_y=jnp.sum(b * y),
        b_sq=jnp.sum(b * b),
    )
    out["pya_sq"] = (
        jnp.maximum(1.0 - out["a_y"] ** 2 / ysq, 0.0) if has_a else jnp.asarray(0.0)
    )
    out["pyb_sq"] = jnp.maximum(out["b_sq"] - out["b_y"] ** 2 / ysq, 0.0)
    out["pya_pyb"] = out["a_b"] - out["a_y"] * out["b_y"] / ysq
    out["pay_sq"] = jnp.maximum(ysq - out["a_y"] ** 2, 0.0) if has_a else ysq
    out["pa1_sq"] = (
        jnp.maximum(float(n) - out["a_1"] ** 2, 0.0) if has_a else jnp.asarray(float(n))
    )
    out["pa1_pay"] = jnp.sum(y) - out["a_1"] * out["a_y"]
    pay_sq = out["pay_sq"]
    out["ppay_pa1_sq"] = jnp.where(
        pay_sq > 0.0,
        jnp.maximum(
            out["pa1_sq"] - out["pa1_pay"] ** 2 / jnp.where(pay_sq > 0, pay_sq, 1.0),
            0.0,
        ),
        out["pa1_sq"],
    )
    return out


def _neg_min_ref(dy, d1, dt, q, s):
    ysq = s["ysq"]
    pyf_sq = jnp.maximum(q - dy * dy / ysq, 0.0)
    degenerate = pyf_sq <= _ZERO_EPS * jnp.maximum(q, 1.0)

    if s["has_a"]:
        a_f = (dt - s["inv1"] * d1) / s["na"]
    else:
        a_f = jnp.zeros_like(dt)
    pya_pyf = a_f - s["a_y"] * dy / ysq

    denom = jnp.sqrt(jnp.maximum(s["pya_sq"] * pyf_sq, 0.0))
    cos = jnp.where(denom > 0.0, pya_pyf / jnp.maximum(denom, _TINY), 0.0)
    case1 = s["has_a"] & (s["pya_sq"] > _ZERO_EPS) & (cos >= 1.0 - _COS_EPS)
    m_colinear = -jnp.sqrt(pyf_sq / jnp.maximum(s["pya_sq"], _TINY)) * s["a_t"]

    b_f = 0.5 * (s["inv2"] * d1 - dt)
    pyb_pyf = b_f - s["b_y"] * dy / ysq
    m_ball = jnp.sqrt(jnp.maximum(s["pyb_sq"] * pyf_sq, 0.0)) - pyb_pyf - dt

    cond = s["pya_pyb"] / jnp.sqrt(jnp.maximum(s["pyb_sq"], _TINY)) - pya_pyf / jnp.sqrt(
        jnp.maximum(pyf_sq, _TINY)
    )
    use_ball = (
        (not s["has_a"])
        | (s["pya_sq"] <= _ZERO_EPS)
        | (s["pyb_sq"] <= _ZERO_EPS)
        | (cond >= 0.0)
    )

    paf_sq = jnp.maximum(q - a_f * a_f, 0.0)
    paf_pay = dy - a_f * s["a_y"]
    paf_pa1 = d1 - a_f * s["a_1"]
    pay_ok = s["pay_sq"] > _ZERO_EPS
    ppf_sq = jnp.where(
        pay_ok,
        jnp.maximum(paf_sq - paf_pay**2 / jnp.maximum(s["pay_sq"], _TINY), 0.0),
        paf_sq,
    )
    pp1_ppf = jnp.where(
        pay_ok,
        paf_pa1 - paf_pay * s["pa1_pay"] / jnp.maximum(s["pay_sq"], _TINY),
        paf_pa1,
    )
    delta = 0.5 * (s["inv2"] - s["inv1"])
    m_plane = (
        delta * (jnp.sqrt(jnp.maximum(ppf_sq * s["ppay_pa1_sq"], 0.0)) - pp1_ppf) - dt
    )

    m = jnp.where(case1, m_colinear, jnp.where(use_ball, m_ball, m_plane))
    return jnp.where(degenerate, 0.0, m)


def screen_bounds_ref(xhat, y, theta1, lambda1, lambda2):
    """Oracle screening bounds: (m,) array, keep iff >= 1."""
    xhat = jnp.asarray(xhat)
    y = jnp.asarray(y)
    theta1 = jnp.asarray(theta1)
    s = shared_scalars(y, theta1, lambda1, lambda2)
    dy = xhat @ y
    d1 = jnp.sum(xhat, axis=1)
    dt = xhat @ theta1
    q = jnp.sum(xhat * xhat, axis=1)
    m_pos = _neg_min_ref(dy, d1, dt, q, s)
    m_neg = _neg_min_ref(-dy, -d1, -dt, q, s)
    return jnp.maximum(m_pos, m_neg)


def svm_grad_ref(x, y, w, b):
    """Oracle for the L2 gradient graph.

    Returns (grad_w, grad_b, loss) for h(w,b) = 0.5*sum(relu(1-y(xw+b))^2).
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    w = jnp.asarray(w)
    z = x @ w + b
    xi = jnp.maximum(1.0 - y * z, 0.0)
    u = xi * y
    gw = -(x.T @ u)
    gb = -jnp.sum(u)
    loss = 0.5 * jnp.sum(xi * xi)
    return gw, gb, loss
