"""L1: the Pallas screening-bound kernel.

Computes, for a block of weighted features (rows of ``xhat``), the paper's
screening bound ``u_j = max_{theta in K} |theta' fhat_j|`` — Algorithm 1
with the three KKT cases of Theorems 6.5/6.7/6.9 — entirely on-chip:

  1. the O(m*n) part is one MXU panel matmul ``D = xhat_blk @ V`` with
     ``V = [y | 1 | theta1 | 0]`` (n x 4, padded to a lane-friendly
     width), fused with the row-norm reduction ``q = rowsum(xhat_blk**2)``;
  2. the per-feature case selection and closed forms are ~40 flops of
     branchless (``jnp.where``) scalar math on the VPU.

TPU mapping (DESIGN.md §Hardware-Adaptation): BlockSpec tiles the feature
axis with ``block_m`` rows per grid step; for the artifact shape set
(n <= 4096) one block is <= 4 MiB of f32 in VMEM. The 24 shared scalars
(functions of lambda1, lambda2, theta1, y only) ride along as a small
vector; on a real TPU they would live in SMEM via scalar prefetch.

MUST be lowered with ``interpret=True`` on this CPU-only image — real TPU
lowering emits a Mosaic custom-call the CPU PJRT client cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Indices into the shared-scalar pack (matches rust SharedContext and
# ref.py). Total SHARED_LEN slots, zero-padded.
S_INV1 = 0
S_INV2 = 1
S_YSQ = 2
S_NA = 3
S_HAS_A = 4
S_A_Y = 5
S_A_1 = 6
S_A_T = 7
S_B_Y = 8
S_B_SQ = 9
S_PYA_SQ = 10
S_PYB_SQ = 11
S_PYA_PYB = 12
S_PAY_SQ = 13
S_PA1_SQ = 14
S_PA1_PAY = 15
S_PPAY_PA1_SQ = 16
SHARED_LEN = 24

# V panel column layout (padded to 8 columns for lane alignment).
V_COLS = 8  # [y, ones, theta1, 0, 0, 0, 0, 0]

_COS_EPS = 1e-9
_ZERO_EPS = 1e-14
_TINY = 1e-30


def _neg_min(dy, d1, dt, q, s):
    """Branchless neg_min = -min_{theta in K} theta' fhat.

    All arguments are (block_m,) vectors except ``s`` which is the shared
    scalar pack. Mirrors rust ``screening::paper::neg_min`` exactly.
    """
    ysq = s[S_YSQ]
    pyf_sq = jnp.maximum(q - dy * dy / ysq, 0.0)
    degenerate = pyf_sq <= _ZERO_EPS * jnp.maximum(q, 1.0)

    has_a = s[S_HAS_A] > 0.5
    a_f = jnp.where(has_a, (dt - s[S_INV1] * d1) / jnp.maximum(s[S_NA], _TINY), 0.0)
    pya_pyf = a_f - s[S_A_Y] * dy / ysq

    # Case 1 (Thm 6.5): P_y(fhat) anti-parallel to P_y(a).
    denom = jnp.sqrt(jnp.maximum(s[S_PYA_SQ] * pyf_sq, 0.0))
    cos = jnp.where(denom > 0.0, pya_pyf / jnp.maximum(denom, _TINY), 0.0)
    case1 = has_a & (s[S_PYA_SQ] > _ZERO_EPS) & (cos >= 1.0 - _COS_EPS)
    m_colinear = -jnp.sqrt(pyf_sq / jnp.maximum(s[S_PYA_SQ], _TINY)) * s[S_A_T]

    # Ball bound (Thm 6.7) — also the safe fallback.
    b_f = 0.5 * (s[S_INV2] * d1 - dt)
    pyb_pyf = b_f - s[S_B_Y] * dy / ysq
    m_ball = jnp.sqrt(jnp.maximum(s[S_PYB_SQ] * pyf_sq, 0.0)) - pyb_pyf - dt

    cond = s[S_PYA_PYB] / jnp.sqrt(jnp.maximum(s[S_PYB_SQ], _TINY)) - pya_pyf / jnp.sqrt(
        jnp.maximum(pyf_sq, _TINY)
    )
    use_ball = (
        (~has_a)
        | (s[S_PYA_SQ] <= _ZERO_EPS)
        | (s[S_PYB_SQ] <= _ZERO_EPS)
        | (cond >= 0.0)
    )

    # Case 3 (Thm 6.9, corrected Eq. 97).
    paf_sq = jnp.maximum(q - a_f * a_f, 0.0)
    paf_pay = dy - a_f * s[S_A_Y]
    paf_pa1 = d1 - a_f * s[S_A_1]
    pay_ok = s[S_PAY_SQ] > _ZERO_EPS
    ppf_sq = jnp.where(
        pay_ok,
        jnp.maximum(paf_sq - paf_pay * paf_pay / jnp.maximum(s[S_PAY_SQ], _TINY), 0.0),
        paf_sq,
    )
    pp1_ppf = jnp.where(
        pay_ok,
        paf_pa1 - paf_pay * s[S_PA1_PAY] / jnp.maximum(s[S_PAY_SQ], _TINY),
        paf_pa1,
    )
    delta = 0.5 * (s[S_INV2] - s[S_INV1])
    m_plane = (
        delta * (jnp.sqrt(jnp.maximum(ppf_sq * s[S_PPAY_PA1_SQ], 0.0)) - pp1_ppf) - dt
    )

    m = jnp.where(case1, m_colinear, jnp.where(use_ball, m_ball, m_plane))
    return jnp.where(degenerate, 0.0, m)


def _screen_kernel(xhat_ref, v_ref, s_ref, u_ref):
    """One grid step: bound for ``block_m`` features.

    xhat_ref: (block_m, n) f32 — weighted features, row-major.
    v_ref:    (n, V_COLS) f32 — [y | 1 | theta1 | 0...] panel.
    s_ref:    (SHARED_LEN,) f32 — shared scalar pack.
    u_ref:    (block_m,) f32 — output bounds.
    """
    xb = xhat_ref[...]
    v = v_ref[...]
    s = s_ref[...]
    # MXU: panel matmul (block_m, n) @ (n, 8); f32 accumulation.
    d = jnp.dot(xb, v, preferred_element_type=jnp.float32)
    # VPU: fused row norm.
    q = jnp.sum(xb * xb, axis=1)
    dy, d1, dt = d[:, 0], d[:, 1], d[:, 2]
    m_pos = _neg_min(dy, d1, dt, q, s)
    m_neg = _neg_min(-dy, -d1, -dt, q, s)
    u_ref[...] = jnp.maximum(m_pos, m_neg)


@functools.partial(jax.jit, static_argnames=("block_m",))
def screen_bounds(xhat, v, shared, *, block_m: int = 256):
    """Screening bounds for all features (rows of ``xhat``).

    Args:
      xhat:   (m, n) f32, rows are weighted features ``fhat_j = y * f_j``.
              Zero-padded rows yield bound 0 (degenerate case) and are
              therefore decision-neutral.
      v:      (n, V_COLS) f32 panel ``[y | 1 | theta1 | 0...]``.
      shared: (SHARED_LEN,) f32 scalar pack (see module constants).
      block_m: feature rows per grid step (must divide padded m).

    Returns:
      (m,) f32 bounds; keep feature j iff ``bounds[j] >= 1``.
    """
    m, n = xhat.shape
    if m % block_m != 0:
        pad = block_m - m % block_m
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
        m_pad = m + pad
    else:
        m_pad = m
    grid = (m_pad // block_m,)
    out = pl.pallas_call(
        _screen_kernel,
        out_shape=jax.ShapeDtypeStruct((m_pad,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n, V_COLS), lambda i: (0, 0)),
            pl.BlockSpec((SHARED_LEN,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xhat, v, shared)
    return out[:m]


def pack_v(y, theta1):
    """Builds the (n, V_COLS) panel from labels and the dual point."""
    y = jnp.asarray(y, jnp.float32)
    theta1 = jnp.asarray(theta1, jnp.float32)
    n = y.shape[0]
    v = jnp.zeros((n, V_COLS), jnp.float32)
    v = v.at[:, 0].set(y)
    v = v.at[:, 1].set(1.0)
    v = v.at[:, 2].set(theta1)
    return v


def pack_shared(y, theta1, lambda1: float, lambda2: float):
    """Computes the shared scalar pack in f64 then casts to f32.

    Mirrors rust ``SharedContext::build`` (elementwise sums to avoid the
    cancellation in ``||theta1 - 1/lambda1||``).
    """
    y = jnp.asarray(y, jnp.float64)
    theta1 = jnp.asarray(theta1, jnp.float64)
    n = y.shape[0]
    inv1 = 1.0 / lambda1
    inv2 = 1.0 / lambda2
    a_raw = theta1 - inv1
    b = 0.5 * (inv2 - theta1)
    ysq = jnp.sum(y * y)
    na = jnp.sqrt(jnp.sum(a_raw * a_raw))
    has_a = na > 1e-12 * (1.0 + inv1 * jnp.sqrt(jnp.asarray(float(n))))
    safe_na = jnp.where(has_a, na, 1.0)
    a_y = jnp.where(has_a, jnp.sum(a_raw * y) / safe_na, 0.0)
    a_1 = jnp.where(has_a, jnp.sum(a_raw) / safe_na, 0.0)
    a_t = jnp.where(has_a, jnp.sum(a_raw * theta1) / safe_na, 0.0)
    a_b = jnp.where(has_a, jnp.sum(a_raw * b) / safe_na, 0.0)
    b_y = jnp.sum(b * y)
    b_sq = jnp.sum(b * b)
    pya_sq = jnp.where(has_a, jnp.maximum(1.0 - a_y * a_y / ysq, 0.0), 0.0)
    pyb_sq = jnp.maximum(b_sq - b_y * b_y / ysq, 0.0)
    pya_pyb = a_b - a_y * b_y / ysq
    pay_sq = jnp.where(has_a, jnp.maximum(ysq - a_y * a_y, 0.0), ysq)
    pa1_sq = jnp.where(has_a, jnp.maximum(n - a_1 * a_1, 0.0), float(n))
    pa1_pay = jnp.where(has_a, jnp.sum(y) - a_1 * a_y, jnp.sum(y))
    ppay_pa1_sq = jnp.where(
        pay_sq > 0.0,
        jnp.maximum(pa1_sq - pa1_pay * pa1_pay / jnp.where(pay_sq > 0, pay_sq, 1.0), 0.0),
        pa1_sq,
    )
    s = jnp.zeros((SHARED_LEN,), jnp.float64)
    vals = {
        S_INV1: inv1,
        S_INV2: inv2,
        S_YSQ: ysq,
        S_NA: na,
        S_HAS_A: jnp.where(has_a, 1.0, 0.0),
        S_A_Y: a_y,
        S_A_1: a_1,
        S_A_T: a_t,
        S_B_Y: b_y,
        S_B_SQ: b_sq,
        S_PYA_SQ: pya_sq,
        S_PYB_SQ: pyb_sq,
        S_PYA_PYB: pya_pyb,
        S_PAY_SQ: pay_sq,
        S_PA1_SQ: pa1_sq,
        S_PA1_PAY: pa1_pay,
        S_PPAY_PA1_SQ: ppay_pa1_sq,
    }
    for k, val in vals.items():
        s = s.at[k].set(val)
    return s.astype(jnp.float32)
