"""L1: Pallas kernel for the solver's gradient hot-spot.

The FISTA gradient is ``grad_w = -X' (xi o y)`` — a transposed panel
matvec over feature columns. The kernel tiles the feature axis: each grid
step loads a (n, block_m) column panel into VMEM and produces block_m
entries of the gradient via an MXU (1, n) x (n, block_m) product.

interpret=True for CPU-PJRT execution (see screen.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xtv_kernel(x_ref, u_ref, out_ref):
    """out = X_panel' u for one feature panel.

    x_ref:  (n, block_m) — column panel of X.
    u_ref:  (n,)         — dense vector.
    out_ref:(block_m,)
    """
    x = x_ref[...]
    u = u_ref[...]
    out_ref[...] = jnp.dot(u, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def xtv(x, u, *, block_m: int = 256):
    """``X' u`` with the feature axis tiled through VMEM.

    Args:
      x: (n, m) f32 sample-major matrix.
      u: (n,) f32.
      block_m: features per grid step (pads m to a multiple).

    Returns:
      (m,) f32.
    """
    n, m = x.shape
    if m % block_m != 0:
        pad = block_m - m % block_m
        x = jnp.pad(x, ((0, 0), (0, pad)))
        m_pad = m + pad
    else:
        m_pad = m
    grid = (m_pad // block_m,)
    out = pl.pallas_call(
        _xtv_kernel,
        out_shape=jax.ShapeDtypeStruct((m_pad,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        interpret=True,
    )(x, u)
    return out[:m]
