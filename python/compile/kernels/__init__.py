"""L1 Pallas kernels: screening bound (screen.py), solver gradient
panels (svm.py), and the pure-jnp oracle (ref.py)."""
