"""L2: the JAX compute graphs, composing the L1 Pallas kernels.

Two graphs get AOT-lowered for the rust runtime:

* ``screen_pass`` — the full screening pass for one feature block:
  the Pallas bound kernel over (block_m, n) weighted features, given the
  [y | 1 | theta1] panel and the shared scalar pack (both produced by the
  rust coordinator, which owns the path state).
* ``svm_grad`` — the FISTA gradient/objective step: margins in jnp
  (O(nnz) elementwise), the feature-axis reduction through the Pallas
  ``xtv`` panel kernel.

These run at build time only; ``aot.py`` lowers them to HLO text that the
rust PJRT runtime loads. Nothing in this package is imported at serving
time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import screen as screen_kernel
from compile.kernels import svm as svm_kernel


def screen_pass(xhat_block, v, shared, *, block_m: int = 256):
    """Screening bounds for one feature block.

    Args:
      xhat_block: (block_m, n) f32, rows are weighted features (zero rows
        are decision-neutral padding).
      v: (n, V_COLS) f32 panel [y | 1 | theta1 | 0...].
      shared: (SHARED_LEN,) f32 shared scalar pack.

    Returns:
      (block_m,) f32 bounds (keep iff >= 1).
    """
    return screen_kernel.screen_bounds(xhat_block, v, shared, block_m=block_m)


def svm_grad(x, y, w, b):
    """Gradient + loss of the squared-hinge term h(w, b) (Eq. 23-25).

    Args:
      x: (n, m) f32 sample-major data.
      y: (n,) f32 labels (+-1).
      w: (m,) f32 weights.
      b: (1,) f32 bias.

    Returns:
      (grad_w (m,), grad_b (1,), loss (1,)).
    """
    z = x @ w + b[0]
    xi = jnp.maximum(1.0 - y * z, 0.0)
    u = xi * y
    gw = -svm_kernel.xtv(x, u)
    gb = -jnp.sum(u)[None]
    loss = (0.5 * jnp.sum(xi * xi))[None]
    return gw, gb, loss


def objective(x, y, w, b, lam):
    """Full primal objective h(w,b) + lam*||w||_1 (shape (1,))."""
    z = x @ w + b[0]
    xi = jnp.maximum(1.0 - y * z, 0.0)
    return (0.5 * jnp.sum(xi * xi) + lam[0] * jnp.sum(jnp.abs(w)))[None]


def fista_step(x, y, w, b, v_w, v_b, lam, inv_l, t_mom):
    """One FISTA step (prox-gradient at the extrapolated point).

    All state flows through so the rust runtime can drive the loop with a
    single compiled executable per shape.

    Returns (w_new, b_new, v_w_new, v_b_new, t_new, loss_at_v).
    """
    gw, gb, loss = svm_grad(x, y, v_w, v_b)
    step = inv_l[0]
    w_arg = v_w - step * gw
    thr = step * lam[0]
    w_new = jnp.sign(w_arg) * jnp.maximum(jnp.abs(w_arg) - thr, 0.0)
    b_new = v_b - step * gb
    t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t_mom[0] * t_mom[0]))
    beta = (t_mom[0] - 1.0) / t_new
    v_w_new = w_new + beta * (w_new - w)
    v_b_new = b_new + beta * (b_new - b)
    return w_new, b_new, v_w_new, v_b_new, t_new[None], loss


def jit_screen_pass(n: int, block_m: int = 256):
    """Jitted screen_pass closed over static shapes (for AOT lowering)."""

    def fn(xhat_block, v, shared):
        return (screen_pass(xhat_block, v, shared, block_m=block_m),)

    spec = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)  # noqa: E731
    args = (
        spec((block_m, n)),
        spec((n, screen_kernel.V_COLS)),
        spec((screen_kernel.SHARED_LEN,)),
    )
    return jax.jit(fn), args


def jit_svm_grad(n: int, m: int):
    """Jitted svm_grad closed over static shapes (for AOT lowering)."""

    def fn(x, y, w, b):
        return svm_grad(x, y, w, b)

    spec = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)  # noqa: E731
    args = (spec((n, m)), spec((n,)), spec((m,)), spec((1,)))
    return jax.jit(fn), args
