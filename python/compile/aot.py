"""AOT lowering: JAX graphs -> HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids, ``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact names encode their shapes so the rust registry needs no side
manifest:

    screen_n{N}_b{B}.hlo.txt   — screen_pass for (B, N) feature blocks
    grad_n{N}_m{M}.hlo.txt     — svm_grad for an (N, M) dense problem

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# The compiled shape registry. The rust runtime pads inputs up to the
# nearest compiled shape, so a small set covers the experiments.
SCREEN_SHAPES = [
    (256, 256),  # (n, block_m)
    (1024, 256),
    (4096, 256),
]
GRAD_SHAPES = [
    (256, 512),  # (n, m)
    (1024, 2048),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(path: str, jitted, args) -> int:
    lowered = jitted.lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        choices=["screen", "grad", "all"],
        default="all",
        help="subset of artifacts to build",
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    total = 0
    if ns.only in ("screen", "all"):
        for n, block_m in SCREEN_SHAPES:
            jitted, args = model.jit_screen_pass(n, block_m)
            path = os.path.join(ns.out_dir, f"screen_n{n}_b{block_m}.hlo.txt")
            size = emit(path, jitted, args)
            print(f"wrote {path} ({size} chars)")
            total += 1
    if ns.only in ("grad", "all"):
        for n, m in GRAD_SHAPES:
            jitted, args = model.jit_svm_grad(n, m)
            path = os.path.join(ns.out_dir, f"grad_n{n}_m{m}.hlo.txt")
            size = emit(path, jitted, args)
            print(f"wrote {path} ({size} chars)")
            total += 1
    print(f"{total} artifacts in {ns.out_dir} (jax {jax.__version__})")


if __name__ == "__main__":
    main()
