"""Pallas screening kernel vs the pure-jnp oracle — the core build-time
correctness signal, swept hypothesis-style over shapes and geometries."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, screen

jax.config.update("jax_enable_x64", True)


def make_case(rng, n, m, frac1=0.7, frac2=0.5, at_lambda_max=False):
    """A random screening problem: data, labels, a dual-feasible theta1."""
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0)
    y[0], y[1] = 1.0, -1.0
    x = rng.standard_normal((m, n))  # feature-major (rows = features)
    # column-normalize features
    x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    xhat = x * y[None, :]
    # lambda_max machinery: b* = (n+ - n-)/n, m_vec = fhat' (y - b*) ...
    n_pos = float((y > 0).sum())
    b_star = (2.0 * n_pos - n) / n
    m_vec = xhat @ (np.ones(n) - b_star * y)  # fhat'(1 - b* y) = f'(y - b*)
    lam_max = np.abs(m_vec).max()
    lam1 = lam_max if at_lambda_max else frac1 * lam_max
    lam2 = frac2 * lam_max
    if at_lambda_max:
        theta1 = np.maximum(0.0, 1.0 - y * b_star) / lam_max
    else:
        # a synthetic dual point: nonnegative, y-orthogonal
        theta1 = rng.random(n) / lam1
        sp = theta1[y > 0].sum()
        sn = theta1[y < 0].sum()
        t = 0.5 * (sp + sn)
        theta1[y > 0] *= t / sp
        theta1[y < 0] *= t / sn
    return xhat, y, theta1, float(lam1), float(lam2)


def run_kernel(xhat, y, theta1, lam1, lam2, block_m=None):
    v = screen.pack_v(y, theta1)
    shared = screen.pack_shared(y, theta1, lam1, lam2)
    kwargs = {}
    if block_m is not None:
        kwargs["block_m"] = block_m
    return screen.screen_bounds(jnp.asarray(xhat, jnp.float32), v, shared, **kwargs)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("shape", [(16, 8), (64, 32), (128, 300)])
def test_kernel_matches_oracle(seed, shape):
    n, m = shape
    rng = np.random.default_rng(seed)
    xhat, y, theta1, lam1, lam2 = make_case(rng, n, m)
    got = np.asarray(run_kernel(xhat, y, theta1, lam1, lam2, block_m=32))
    want = np.asarray(
        ref.screen_bounds_ref(
            jnp.asarray(xhat, jnp.float64),
            jnp.asarray(y, jnp.float64),
            jnp.asarray(theta1, jnp.float64),
            lam1,
            lam2,
        )
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("at_lambda_max", [True, False])
def test_kernel_geometry_regimes(at_lambda_max):
    # at lambda_max the half-space normal degenerates to ~y (ball case
    # everywhere); interior theta1 exercises the plane case.
    rng = np.random.default_rng(42)
    xhat, y, theta1, lam1, lam2 = make_case(
        rng, 64, 128, at_lambda_max=at_lambda_max
    )
    got = np.asarray(run_kernel(xhat, y, theta1, lam1, lam2, block_m=64))
    want = np.asarray(
        ref.screen_bounds_ref(
            jnp.asarray(xhat, jnp.float64),
            jnp.asarray(y, jnp.float64),
            jnp.asarray(theta1, jnp.float64),
            lam1,
            lam2,
        )
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_padding_rows_are_decision_neutral():
    # m not a multiple of block_m: padded rows must not leak NaN/garbage
    # and must produce bound exactly 0 internally (degenerate case).
    rng = np.random.default_rng(7)
    xhat, y, theta1, lam1, lam2 = make_case(rng, 32, 50)
    got = run_kernel(xhat, y, theta1, lam1, lam2, block_m=32)
    assert got.shape == (50,)
    assert np.all(np.isfinite(np.asarray(got)))


def test_zero_feature_screened():
    rng = np.random.default_rng(8)
    xhat, y, theta1, lam1, lam2 = make_case(rng, 32, 10)
    xhat[3, :] = 0.0
    got = np.asarray(run_kernel(xhat, y, theta1, lam1, lam2, block_m=10))
    assert got[3] == 0.0


def test_y_parallel_feature_screened():
    # f = const => fhat = const*y: degenerate case. In f32 the
    # ||P_y(fhat)||^2 cancellation leaves noise ~1e-7, so the kernel may
    # resolve it via the ball case instead of the exact-0 branch — either
    # way the bound must be far below the keep threshold of 1.
    rng = np.random.default_rng(9)
    xhat, y, theta1, lam1, lam2 = make_case(rng, 32, 10)
    xhat[5, :] = 0.17 * y
    got = np.asarray(run_kernel(xhat, y, theta1, lam1, lam2, block_m=10))
    assert abs(got[5]) < 0.05


def test_block_size_invariance():
    rng = np.random.default_rng(10)
    xhat, y, theta1, lam1, lam2 = make_case(rng, 48, 96)
    a = np.asarray(run_kernel(xhat, y, theta1, lam1, lam2, block_m=16))
    b = np.asarray(run_kernel(xhat, y, theta1, lam1, lam2, block_m=96))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_bounds_shrink_with_smaller_gap():
    # lambda2 closer to lambda1 => smaller ball => smaller bounds.
    rng = np.random.default_rng(11)
    xhat, y, theta1, lam1, _ = make_case(rng, 40, 80)
    near = np.asarray(run_kernel(xhat, y, theta1, lam1, 0.95 * lam1, block_m=80))
    far = np.asarray(run_kernel(xhat, y, theta1, lam1, 0.50 * lam1, block_m=80))
    assert (near <= far + 1e-5).all()
