"""L2 graph tests: gradient vs autodiff, FISTA step semantics, shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels import svm as svm_kernel


def rand_problem(rng, n, m):
    x = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    y = jnp.asarray(np.where(rng.random(n) < 0.5, 1.0, -1.0), jnp.float32)
    w = jnp.asarray(0.1 * rng.standard_normal(m), jnp.float32)
    b = jnp.asarray([0.2], jnp.float32)
    return x, y, w, b


@pytest.mark.parametrize("shape", [(8, 5), (64, 100), (100, 257)])
def test_xtv_matches_dense(shape):
    n, m = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    u = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = svm_kernel.xtv(x, u, block_m=64)
    want = x.T @ u
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", range(3))
def test_svm_grad_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    x, y, w, b = rand_problem(rng, 40, 30)
    gw, gb, loss = model.svm_grad(x, y, w, b)
    gw_ref, gb_ref, loss_ref = ref.svm_grad_ref(x, y, w, float(b[0]))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(gb[0]), float(gb_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(loss[0]), float(loss_ref), rtol=1e-5)


def test_svm_grad_matches_autodiff():
    rng = np.random.default_rng(5)
    x, y, w, b = rand_problem(rng, 30, 20)

    def loss_fn(w, b):
        z = x @ w + b
        xi = jnp.maximum(1.0 - y * z, 0.0)
        return 0.5 * jnp.sum(xi * xi)

    gw_ad = jax.grad(loss_fn, argnums=0)(w, b[0])
    gb_ad = jax.grad(loss_fn, argnums=1)(w, b[0])
    gw, gb, _ = model.svm_grad(x, y, w, b)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ad), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(gb[0]), float(gb_ad), rtol=1e-4, atol=1e-4)


def test_objective_matches_pieces():
    rng = np.random.default_rng(6)
    x, y, w, b = rand_problem(rng, 25, 15)
    lam = jnp.asarray([0.3], jnp.float32)
    obj = model.objective(x, y, w, b, lam)
    _, _, loss = model.svm_grad(x, y, w, b)
    want = float(loss[0]) + 0.3 * float(jnp.sum(jnp.abs(w)))
    np.testing.assert_allclose(float(obj[0]), want, rtol=1e-5)


def test_fista_step_decreases_objective():
    rng = np.random.default_rng(7)
    x, y, w, b = rand_problem(rng, 50, 30)
    w = jnp.zeros_like(w)
    lam = jnp.asarray([0.1], jnp.float32)
    # Lipschitz upper bound: ||[X 1]||_F^2 is safe
    l = float(jnp.sum(x * x)) + 50.0
    inv_l = jnp.asarray([1.0 / l], jnp.float32)
    t_mom = jnp.asarray([1.0], jnp.float32)
    obj0 = float(model.objective(x, y, w, b, lam)[0])
    w1, b1, vw1, vb1, t1, _ = model.fista_step(x, y, w, b, w, b, lam, inv_l, t_mom)
    obj1 = float(model.objective(x, y, w1, b1, lam)[0])
    assert obj1 <= obj0 + 1e-6, (obj0, obj1)
    assert float(t1[0]) > 1.0
    assert vw1.shape == w.shape and vb1.shape == b.shape


def test_jit_wrappers_lower():
    # The AOT entry points must lower without error (cheap smoke; full
    # HLO emission is exercised by `make artifacts`).
    jitted, args = model.jit_screen_pass(64, 32)
    lowered = jitted.lower(*args)
    assert "func" in str(lowered.compiler_ir("stablehlo"))
    jitted, args = model.jit_svm_grad(32, 16)
    lowered = jitted.lower(*args)
    assert lowered is not None
